//! Sparsity-structure statistics: nnz distribution, row imbalance.
//!
//! Row imbalance drives two things the paper cares about: warp load
//! imbalance on the GPU (irregular CSR rows) and, in our TPU adaptation,
//! the ELL padding overhead (`ablation_sparsity` bench).

use super::CsrMatrix;


/// Aggregate sparsity statistics of a weight matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityStats {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// `1 - nnz / (rows * cols)`.
    pub sparsity: f64,
    /// Smallest per-row nonzero count.
    pub min_row_nnz: usize,
    /// Largest per-row nonzero count (the ELL `Kmax`).
    pub max_row_nnz: usize,
    /// Mean per-row nonzero count.
    pub mean_row_nnz: f64,
    /// max / mean row population; 1.0 = perfectly balanced.
    pub imbalance: f64,
    /// CSR storage footprint (values + colidx + rowptr).
    pub csr_bytes: usize,
    /// Dense storage footprint for comparison.
    pub dense_bytes: usize,
}

impl SparsityStats {
    /// Compute the statistics of one CSR matrix.
    pub fn of(m: &CsrMatrix) -> Self {
        let row_nnz: Vec<usize> = (0..m.rows).map(|r| m.row_nnz(r)).collect();
        let mean = if m.rows == 0 {
            0.0
        } else {
            m.nnz() as f64 / m.rows as f64
        };
        let max = row_nnz.iter().copied().max().unwrap_or(0);
        Self {
            rows: m.rows,
            cols: m.cols,
            nnz: m.nnz(),
            sparsity: m.sparsity(),
            min_row_nnz: row_nnz.iter().copied().min().unwrap_or(0),
            max_row_nnz: max,
            mean_row_nnz: mean,
            imbalance: if mean > 0.0 { max as f64 / mean } else { 1.0 },
            csr_bytes: m.memory_bytes(),
            dense_bytes: m.dense_bytes(),
        }
    }
}

/// Alias used by the ablation bench reporting.
pub type RowImbalance = f64;

/// Histogram of per-row nonzero counts with `buckets` equal-width bins
/// over `[0, cols]`.
pub fn row_nnz_histogram(m: &CsrMatrix, buckets: usize) -> Vec<usize> {
    assert!(buckets > 0);
    let mut hist = vec![0usize; buckets];
    if m.cols == 0 {
        return hist;
    }
    for r in 0..m.rows {
        let nnz = m.row_nnz(r);
        let b = (nnz * buckets / (m.cols + 1)).min(buckets - 1);
        hist[b] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune_magnitude;
    use crate::util::Rng;

    #[test]
    fn stats_on_known_matrix() {
        let dense = vec![
            1., 0., 0., //
            1., 1., 0., //
            1., 1., 1.,
        ];
        let m = CsrMatrix::from_dense(3, 3, &dense);
        let s = SparsityStats::of(&m);
        assert_eq!(s.nnz, 6);
        assert_eq!(s.min_row_nnz, 1);
        assert_eq!(s.max_row_nnz, 3);
        assert!((s.mean_row_nnz - 2.0).abs() < 1e-12);
        assert!((s.imbalance - 1.5).abs() < 1e-12);
    }

    #[test]
    fn magnitude_pruned_matrices_are_roughly_balanced() {
        // With i.i.d. weights, magnitude pruning spreads nonzeros evenly:
        // imbalance should be modest (< 1.5 at 0.9 sparsity on wide rows).
        let mut rng = Rng::new(77);
        let mut w = rng.normal_vec(256 * 1152);
        prune_magnitude(&mut w, 0.9);
        let m = CsrMatrix::from_dense(256, 1152, &w);
        let s = SparsityStats::of(&m);
        assert!(s.imbalance < 1.5, "imbalance {}", s.imbalance);
    }

    #[test]
    fn histogram_buckets_sum_to_rows() {
        let mut rng = Rng::new(5);
        let mut w = rng.normal_vec(64 * 100);
        prune_magnitude(&mut w, 0.8);
        let m = CsrMatrix::from_dense(64, 100, &w);
        let h = row_nnz_histogram(&m, 10);
        assert_eq!(h.iter().sum::<usize>(), 64);
    }
}
