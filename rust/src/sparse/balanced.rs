//! Bank-balanced sparse layout for the vectorized microkernel —
//! a sliced-ELL variant after Balanced Sparsity (PAPERS.md, arXiv
//! 1811.00206) and the ELL slicing literature.
//!
//! The vector kernel processes register blocks of `mr` consecutive
//! output channels. With raw CSR those channels carry *different* nnz
//! counts, so inside one register block the per-channel inner loops
//! have different trip counts: the block's progress is gated by its
//! densest row while sparser rows finish early — the lane-idle problem
//! Balanced Sparsity prunes away. [`BalancedCsr`] fixes it at the
//! *layout* level instead of the pruning level: rows are grouped into
//! **banks** of `bank_rows` (= the plan's `mr`) consecutive rows, and
//! every row of a bank is padded with explicit `(0.0, colidx 0)` slots
//! to the bank's max row nnz. Within a bank every row then has the
//! identical static trip count, and the padded slots are arithmetic
//! no-ops (`fmaf(0, x, acc)` returns `acc` bit-for-bit for finite `x`,
//! since a running sum in the kernels is never `-0.0`).
//!
//! Unlike full ELL (one global `k = max_row_nnz`), padding is per-bank,
//! so one dense row inflates only its own `mr`-row bank — the padding
//! overhead of skewed layers stays proportional to the skew, not to the
//! worst row. The layout is **lossless**: stored CSR matrices never
//! contain explicit zeros ([`CsrMatrix::validate`]), so dropping the
//! zero-valued slots reconstructs the original CSR exactly, in order.

use super::CsrMatrix;

/// A CSR matrix re-packed into nnz-balanced banks of consecutive rows
/// (sliced ELL): within each bank of `bank_rows` rows, every row holds
/// exactly the bank's `k` slots (real nonzeros in CSR column order,
/// then zero padding), so a register block that walks one bank has one
/// static trip count for all its rows.
#[derive(Clone, Debug, PartialEq)]
pub struct BalancedCsr {
    /// Row count of the original matrix.
    pub rows: usize,
    /// Column count of the original matrix.
    pub cols: usize,
    /// Rows per bank — the register-block height (`TilePolicy::mr`)
    /// this layout was balanced for.
    pub bank_rows: usize,
    /// Per-bank slot count `k` = max row nnz within the bank.
    pub bank_k: Vec<usize>,
    /// Start offset of each bank into `values`/`colidx` (banks + 1
    /// entries; bank `b` occupies `bank_ptr[b]..bank_ptr[b + 1]`).
    pub bank_ptr: Vec<usize>,
    /// Slot values, row-major within each bank; padding slots are 0.0.
    pub values: Vec<f32>,
    /// Slot column ids; padding slots use column 0 (always in range,
    /// and harmless because the paired value is 0.0).
    pub colidx: Vec<u32>,
}

impl BalancedCsr {
    /// Re-pack `csr` into banks of `bank_rows` consecutive rows. The
    /// last bank may be short when `rows % bank_rows != 0`.
    pub fn from_csr(csr: &CsrMatrix, bank_rows: usize) -> Self {
        let bank_rows = bank_rows.max(1);
        let n_banks = csr.rows.div_ceil(bank_rows);
        let mut bank_k = Vec::with_capacity(n_banks);
        let mut bank_ptr = Vec::with_capacity(n_banks + 1);
        let mut values = Vec::new();
        let mut colidx = Vec::new();
        bank_ptr.push(0);
        for b in 0..n_banks {
            let r0 = b * bank_rows;
            let r1 = ((b + 1) * bank_rows).min(csr.rows);
            let k = (r0..r1).map(|r| csr.row_nnz(r)).max().unwrap_or(0);
            for r in r0..r1 {
                let range = csr.row_range(r);
                values.extend_from_slice(&csr.values[range.clone()]);
                colidx.extend_from_slice(&csr.colidx[range.clone()]);
                let pad = k - range.len();
                values.extend(std::iter::repeat(0.0).take(pad));
                colidx.extend(std::iter::repeat(0u32).take(pad));
            }
            bank_k.push(k);
            bank_ptr.push(values.len());
        }
        Self {
            rows: csr.rows,
            cols: csr.cols,
            bank_rows,
            bank_k,
            bank_ptr,
            values,
            colidx,
        }
    }

    /// The `k` slots of row `r`: `(values, colidx)` slices of identical
    /// length — real nonzeros in CSR order followed by zero padding.
    #[inline(always)]
    pub fn row_slots(&self, r: usize) -> (&[f32], &[u32]) {
        let b = r / self.bank_rows;
        let k = self.bank_k[b];
        let start = self.bank_ptr[b] + (r - b * self.bank_rows) * k;
        (
            &self.values[start..start + k],
            &self.colidx[start..start + k],
        )
    }

    /// Number of banks.
    pub fn banks(&self) -> usize {
        self.bank_k.len()
    }

    /// Total slots stored (nnz + padding).
    pub fn slots(&self) -> usize {
        self.values.len()
    }

    /// Stored nonzeros (excluding padding) — equals the source CSR's
    /// nnz by construction.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Fraction of slots that are padding — the cost of balancing,
    /// analogous to [`super::EllMatrix`]'s padding overhead but bounded
    /// per `bank_rows`-row bank instead of per matrix.
    pub fn padding_ratio(&self) -> f64 {
        if self.slots() == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / self.slots() as f64
    }

    /// Reconstruct the original CSR by dropping the padding slots.
    /// Lossless because source matrices never store explicit zeros.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut values = Vec::with_capacity(self.nnz());
        let mut colidx = Vec::with_capacity(self.nnz());
        let mut rowptr = Vec::with_capacity(self.rows + 1);
        rowptr.push(0u32);
        for r in 0..self.rows {
            let (vals, cols) = self.row_slots(r);
            for (v, c) in vals.iter().zip(cols) {
                if *v != 0.0 {
                    values.push(*v);
                    colidx.push(*c);
                }
            }
            rowptr.push(values.len() as u32);
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            values,
            colidx,
            rowptr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune_magnitude;
    use crate::util::Rng;

    fn random_csr(rows: usize, cols: usize, sparsity: f32, seed: u64) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut dense = rng.normal_vec(rows * cols);
        if sparsity > 0.0 {
            prune_magnitude(&mut dense, sparsity);
        }
        CsrMatrix::from_dense(rows, cols, &dense)
    }

    #[test]
    fn rows_within_a_bank_carry_identical_slot_counts() {
        // The balance property: zero spread inside every bank.
        for (rows, cols, sp, bank_rows) in
            [(16, 36, 0.7, 4), (13, 50, 0.9, 4), (7, 20, 0.5, 3), (9, 9, 0.0, 8)]
        {
            let csr = random_csr(rows, cols, sp, 42 + rows as u64);
            let bal = BalancedCsr::from_csr(&csr, bank_rows);
            for b in 0..bal.banks() {
                let r0 = b * bank_rows;
                let r1 = ((b + 1) * bank_rows).min(rows);
                let counts: Vec<usize> = (r0..r1).map(|r| bal.row_slots(r).0.len()).collect();
                assert!(
                    counts.iter().all(|&k| k == bal.bank_k[b]),
                    "bank {b} slot spread: {counts:?}"
                );
                // And k is tight: the densest row of the bank fills it.
                let max_nnz = (r0..r1).map(|r| csr.row_nnz(r)).max().unwrap();
                assert_eq!(bal.bank_k[b], max_nnz, "bank {b} over-padded");
            }
        }
    }

    #[test]
    fn round_trips_to_csr_losslessly() {
        for (rows, cols, sp, bank_rows) in [
            (16, 36, 0.7, 4),
            (13, 50, 0.95, 4),
            (5, 8, 0.5, 2),
            (6, 12, 0.0, 16), // bank_rows > rows: one short bank
        ] {
            let csr = random_csr(rows, cols, sp, 7 + cols as u64);
            let bal = BalancedCsr::from_csr(&csr, bank_rows);
            let back = bal.to_csr();
            assert_eq!(back, csr, "{rows}x{cols} sp{sp} bank{bank_rows}");
            back.validate().unwrap();
            assert_eq!(bal.nnz(), csr.nnz());
        }
    }

    #[test]
    fn padding_slots_are_zero_valued_column_zero() {
        let csr = random_csr(12, 30, 0.8, 11);
        let bal = BalancedCsr::from_csr(&csr, 4);
        let mut padding = 0;
        for r in 0..bal.rows {
            let (vals, cols) = bal.row_slots(r);
            let nnz = csr.row_nnz(r);
            // Real slots first, in CSR order.
            let range = csr.row_range(r);
            assert_eq!(&vals[..nnz], &csr.values[range.clone()]);
            assert_eq!(&cols[..nnz], &csr.colidx[range]);
            // Then padding: value 0.0, column 0.
            assert!(vals[nnz..].iter().all(|&v| v == 0.0));
            assert!(cols[nnz..].iter().all(|&c| c == 0));
            padding += vals.len() - nnz;
        }
        assert_eq!(bal.slots(), bal.nnz() + padding);
        let want_ratio = padding as f64 / bal.slots() as f64;
        assert!((bal.padding_ratio() - want_ratio).abs() < 1e-12);
    }

    #[test]
    fn empty_and_all_zero_matrices() {
        let empty = CsrMatrix::from_dense(4, 6, &vec![0.0; 24]);
        let bal = BalancedCsr::from_csr(&empty, 4);
        assert_eq!(bal.slots(), 0);
        assert_eq!(bal.padding_ratio(), 0.0);
        assert_eq!(bal.to_csr(), empty);
        for r in 0..4 {
            assert!(bal.row_slots(r).0.is_empty());
        }
    }

    #[test]
    fn one_dense_row_inflates_only_its_own_bank() {
        // Rows 0..8 with 1 nnz each except row 5 fully dense: banks of
        // 4 keep bank 0 at k=1; only bank 1 pays the dense row's k.
        let cols = 10;
        let mut dense = vec![0.0f32; 8 * cols];
        for r in 0..8 {
            dense[r * cols + (r % cols)] = 1.0 + r as f32;
        }
        for c in 0..cols {
            dense[5 * cols + c] = 0.5 + c as f32;
        }
        let csr = CsrMatrix::from_dense(8, cols, &dense);
        let bal = BalancedCsr::from_csr(&csr, 4);
        assert_eq!(bal.bank_k, vec![1, cols]);
        assert_eq!(bal.to_csr(), csr);
    }
}
