//! Compressed Sparse Row matrices, exactly as the paper's Fig 4:
//! `value` (nnz floats), `colidx` (nnz column ids), `rowptr` (rows+1).



/// A CSR matrix of `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// The nnz stored values, row-major.
    pub values: Vec<f32>,
    /// Column id of each stored value.
    pub colidx: Vec<u32>,
    /// Row start offsets into `values`/`colidx` (`rows + 1` entries).
    pub rowptr: Vec<u32>,
}

impl CsrMatrix {
    /// Build from a dense row-major matrix, keeping every nonzero.
    pub fn from_dense(rows: usize, cols: usize, dense: &[f32]) -> Self {
        assert_eq!(dense.len(), rows * cols);
        let mut values = Vec::new();
        let mut colidx = Vec::new();
        let mut rowptr = Vec::with_capacity(rows + 1);
        rowptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    values.push(v);
                    colidx.push(c as u32);
                }
            }
            rowptr.push(values.len() as u32);
        }
        Self {
            rows,
            cols,
            values,
            colidx,
            rowptr,
        }
    }

    /// Expand back to a dense row-major matrix.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for j in self.row_range(r) {
                out[r * self.cols + self.colidx[j] as usize] = self.values[j];
            }
        }
        out
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Index range of row `r` into `values`/`colidx`.
    #[inline(always)]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.rowptr[r] as usize..self.rowptr[r + 1] as usize
    }

    /// Nonzeros in row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.rowptr[r + 1] - self.rowptr[r]) as usize
    }

    /// The largest row population — the ELL padding factor `Kmax`.
    pub fn max_row_nnz(&self) -> usize {
        (0..self.rows).map(|r| self.row_nnz(r)).max().unwrap_or(0)
    }

    /// Sparsity = fraction of zero cells (paper §2.3 definition).
    pub fn sparsity(&self) -> f64 {
        let total = (self.rows * self.cols) as f64;
        if total == 0.0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / total
    }

    /// Bytes consumed by the compressed form — the paper's §2.3 formula
    /// `(2*nnz + M + 1) * 4`.
    pub fn memory_bytes(&self) -> usize {
        (2 * self.nnz() + self.rows + 1) * 4
    }

    /// Bytes the dense form would consume.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Iterate `(row, col, value)` triplets in row-major order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        (0..self.rows).flat_map(move |r| {
            self.row_range(r)
                .map(move |j| (r, self.colidx[j] as usize, self.values[j]))
        })
    }

    /// Internal consistency check (monotone rowptr, in-range colidx,
    /// no explicit zeros). Used by property tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.rows + 1 {
            return Err(format!("rowptr len {} != rows+1", self.rowptr.len()));
        }
        if self.rowptr[0] != 0 || *self.rowptr.last().unwrap() as usize != self.nnz() {
            return Err("rowptr endpoints wrong".into());
        }
        if self.rowptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("rowptr not monotone".into());
        }
        if self.colidx.len() != self.values.len() {
            return Err("colidx/values length mismatch".into());
        }
        if self.colidx.iter().any(|&c| c as usize >= self.cols) {
            return Err("colidx out of range".into());
        }
        if self.values.iter().any(|&v| v == 0.0) {
            return Err("explicit zero stored".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact matrix from the paper's Fig 4.
    fn fig4() -> (usize, usize, Vec<f32>) {
        let dense = vec![
            10., 20., 0., 0., 0., 0., //
            0., 30., 0., 40., 0., 0., //
            0., 0., 50., 60., 70., 0., //
            0., 0., 0., 0., 0., 80.,
        ];
        (4, 6, dense)
    }

    #[test]
    fn fig4_arrays_match_paper() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        assert_eq!(m.values, vec![10., 20., 30., 40., 50., 60., 70., 80.]);
        assert_eq!(m.colidx, vec![0, 1, 1, 3, 2, 3, 4, 5]);
        assert_eq!(m.rowptr, vec![0, 2, 4, 7, 8]);
        m.validate().unwrap();
    }

    #[test]
    fn dense_roundtrip() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        assert_eq!(m.to_dense(), dense);
    }

    #[test]
    fn row_helpers() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(2), 3);
        assert_eq!(m.max_row_nnz(), 3);
        assert_eq!(m.row_range(2), 4..7);
    }

    #[test]
    fn memory_formula_from_paper() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        // (2*8 + 4 + 1) * 4 = 84 bytes.
        assert_eq!(m.memory_bytes(), 84);
        assert_eq!(m.dense_bytes(), 96);
    }

    #[test]
    fn sparsity_definition() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        assert!((m.sparsity() - (1.0 - 8.0 / 24.0)).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_dense(3, 4, &vec![0.0; 12]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.max_row_nnz(), 0);
        m.validate().unwrap();
        assert_eq!(m.to_dense(), vec![0.0; 12]);
    }

    #[test]
    fn iter_triplets() {
        let (r, c, dense) = fig4();
        let m = CsrMatrix::from_dense(r, c, &dense);
        let trips: Vec<_> = m.iter().collect();
        assert_eq!(trips[0], (0, 0, 10.0));
        assert_eq!(trips[7], (3, 5, 80.0));
        assert_eq!(trips.len(), 8);
    }
}
