//! ELLPACK format: every row padded to the same nonzero count.
//!
//! The paper's CUDA kernel walks CSR rows with dynamic `rowptr` bounds; the
//! TPU adaptation (DESIGN.md §6) needs a *static* inner trip count, so rows
//! are padded to `k = max_row_nnz` with `value = 0` entries whose column
//! index points at a safe (in-range) location. The wasted MACs are
//! multiplications by zero — numerically inert.

use super::CsrMatrix;


/// An ELLPACK matrix: `rows x k` slots stored row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct EllMatrix {
    /// Row count.
    pub rows: usize,
    /// Logical column count (of the dense equivalent).
    pub cols: usize,
    /// Slots per row (`Kmax`, possibly rounded up for alignment).
    pub k: usize,
    /// `rows * k` values; padding slots hold `0.0`.
    pub values: Vec<f32>,
    /// `rows * k` column indices; padding slots hold `0` (safe, in-range).
    pub colidx: Vec<u32>,
}

impl EllMatrix {
    /// Convert from CSR, padding every row to `max_row_nnz` rounded up to a
    /// multiple of `align` (use `align = 1` for tight packing; the Pallas
    /// kernel prefers multiples of 8 so the nnz loop tiles evenly).
    pub fn from_csr(csr: &CsrMatrix, align: usize) -> Self {
        assert!(align > 0);
        let kmax = csr.max_row_nnz();
        let k = if kmax == 0 {
            align
        } else {
            kmax.div_ceil(align) * align
        };
        let mut values = vec![0.0f32; csr.rows * k];
        let mut colidx = vec![0u32; csr.rows * k];
        for r in 0..csr.rows {
            for (slot, j) in csr.row_range(r).enumerate() {
                values[r * k + slot] = csr.values[j];
                colidx[r * k + slot] = csr.colidx[j];
            }
        }
        Self {
            rows: csr.rows,
            cols: csr.cols,
            k,
            values,
            colidx,
        }
    }

    /// Convert from CSR with an externally fixed slot count `k` — used
    /// when the slot budget comes from an AOT artifact's manifest and the
    /// runtime must produce arrays of exactly that shape. Panics if any
    /// row exceeds `k` (the manifest contract guarantees the fit for
    /// per-row-pruned weights).
    pub fn from_csr_fixed_k(csr: &CsrMatrix, k: usize) -> Self {
        assert!(
            csr.max_row_nnz() <= k,
            "row with {} nonzeros exceeds manifest ELL k={}",
            csr.max_row_nnz(),
            k
        );
        let mut values = vec![0.0f32; csr.rows * k];
        let mut colidx = vec![0u32; csr.rows * k];
        for r in 0..csr.rows {
            for (slot, j) in csr.row_range(r).enumerate() {
                values[r * k + slot] = csr.values[j];
                colidx[r * k + slot] = csr.colidx[j];
            }
        }
        Self {
            rows: csr.rows,
            cols: csr.cols,
            k,
            values,
            colidx,
        }
    }

    /// Expand to dense row-major (padding slots contribute nothing).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for s in 0..self.k {
                let v = self.values[r * self.k + s];
                if v != 0.0 {
                    out[r * self.cols + self.colidx[r * self.k + s] as usize] = v;
                }
            }
        }
        out
    }

    /// Stored slots (including padding).
    pub fn slots(&self) -> usize {
        self.rows * self.k
    }

    /// True nonzeros (padding excluded).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Padding overhead: slots / nnz. 1.0 = no waste. The ablation bench
    /// `ablation_sparsity` sweeps this against sparsity level.
    pub fn padding_overhead(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            return f64::INFINITY;
        }
        self.slots() as f64 / nnz as f64
    }

    /// Value row `r` (length `k`).
    pub fn value_row(&self, r: usize) -> &[f32] {
        &self.values[r * self.k..(r + 1) * self.k]
    }

    /// Column-index row `r` (length `k`).
    pub fn colidx_row(&self, r: usize) -> &[u32] {
        &self.colidx[r * self.k..(r + 1) * self.k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig4_csr() -> CsrMatrix {
        let dense = vec![
            10., 20., 0., 0., 0., 0., //
            0., 30., 0., 40., 0., 0., //
            0., 0., 50., 60., 70., 0., //
            0., 0., 0., 0., 0., 80.,
        ];
        CsrMatrix::from_dense(4, 6, &dense)
    }

    #[test]
    fn from_csr_tight() {
        let e = EllMatrix::from_csr(&fig4_csr(), 1);
        assert_eq!(e.k, 3); // row 2 has 3 nonzeros
        assert_eq!(e.value_row(0), &[10., 20., 0.]);
        assert_eq!(e.value_row(2), &[50., 60., 70.]);
        assert_eq!(e.colidx_row(2), &[2, 3, 4]);
        assert_eq!(e.nnz(), 8);
    }

    #[test]
    fn from_csr_aligned() {
        let e = EllMatrix::from_csr(&fig4_csr(), 8);
        assert_eq!(e.k, 8);
        assert_eq!(e.slots(), 32);
        assert_eq!(e.nnz(), 8);
        assert_eq!(e.padding_overhead(), 4.0);
    }

    #[test]
    fn dense_roundtrip_through_ell() {
        let csr = fig4_csr();
        let e = EllMatrix::from_csr(&csr, 4);
        assert_eq!(e.to_dense(), csr.to_dense());
    }

    #[test]
    fn empty_rows_are_all_padding() {
        let dense = vec![0., 0., 1., 0., 0., 0.];
        let csr = CsrMatrix::from_dense(3, 2, &dense);
        let e = EllMatrix::from_csr(&csr, 1);
        assert_eq!(e.k, 1);
        assert_eq!(e.value_row(0), &[0.0]);
        assert_eq!(e.value_row(1), &[1.0]);
        assert_eq!(e.value_row(2), &[0.0]);
        assert_eq!(e.to_dense(), dense);
    }

    #[test]
    fn all_zero_matrix_gets_min_k() {
        let csr = CsrMatrix::from_dense(2, 3, &vec![0.0; 6]);
        let e = EllMatrix::from_csr(&csr, 8);
        assert_eq!(e.k, 8);
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.to_dense(), vec![0.0; 6]);
    }
}
