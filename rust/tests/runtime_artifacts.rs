//! Integration: AOT artifacts (Pallas -> HLO -> PJRT) vs the native Rust
//! kernels on identical inputs — the cross-language correctness seal.
//!
//! Compiled only with the `pjrt` cargo feature (the PJRT engine needs the
//! `xla` bindings, absent from the dependency-free default build), and
//! additionally requires `make artifacts` to have produced `artifacts/`;
//! tests skip (with a loud message) when the directory is absent so
//! `cargo test --features pjrt` stays runnable on a fresh checkout.
#![cfg(feature = "pjrt")]

use escoin::config::ConvShape;
use escoin::conv::{direct_dense, ConvWeights};
use escoin::runtime::Engine;
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::Rng;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn engine() -> Option<Engine> {
    artifact_dir().map(|d| Engine::new(d).expect("engine"))
}

fn case(shape: &ConvShape, batch: usize, seed: u64) -> (Tensor4, ConvWeights) {
    let mut rng = Rng::new(seed);
    let x = Tensor4::random_activations(Dims4::new(batch, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(shape, &mut rng);
    (x, w)
}

#[test]
fn every_layer_artifact_matches_native_reference() {
    let Some(engine) = engine() else { return };
    let names: Vec<String> = engine
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "layer")
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 15, "expected 5 layers x 3 methods");
    for name in names {
        let loaded = engine.load(&name).expect("load");
        let shape = loaded.artifact.shape.clone().expect("layer shape");
        let (x, w) = case(&shape, loaded.artifact.batch, 0xE5C0 + name.len() as u64);
        let weight_lits = loaded.weight_literals(&w).expect("weights");
        let got = loaded.run(&x, &weight_lits).expect("execute");
        let want = direct_dense(&shape, &x, &w);
        assert!(
            got.allclose(&want, 1e-3, 1e-3),
            "{name}: artifact disagrees with native reference (max diff {})",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn methods_agree_with_each_other_through_pjrt() {
    let Some(engine) = engine() else { return };
    let layer = "alexnet_conv3";
    let arts = engine.manifest().for_layer(layer);
    assert_eq!(arts.len(), 3, "three methods per layer");
    let shape = arts[0].shape.clone().unwrap();
    let batch = arts[0].batch;
    let (x, w) = case(&shape, batch, 99);
    let mut outs = Vec::new();
    for a in arts {
        let loaded = engine.load(&a.name).unwrap();
        let lits = loaded.weight_literals(&w).unwrap();
        outs.push((a.name.clone(), loaded.run(&x, &lits).unwrap()));
    }
    for pair in outs.windows(2) {
        assert!(
            pair[0].1.allclose(&pair[1].1, 1e-3, 1e-3),
            "{} vs {} disagree",
            pair[0].0,
            pair[1].0
        );
    }
}

#[test]
fn minicnn_model_artifacts_agree_across_methods() {
    let Some(engine) = engine() else { return };
    let arts: Vec<_> = engine
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "model")
        .cloned()
        .collect();
    assert_eq!(arts.len(), 3);
    let layers = arts[0].layers.clone();
    assert_eq!(layers.len(), 3);
    let mut rng = Rng::new(4242);
    let l1 = &layers[0];
    let x = Tensor4::random_activations(Dims4::new(arts[0].batch, l1.c, l1.h, l1.w), &mut rng);
    let convs: Vec<ConvWeights> = layers
        .iter()
        .map(|l| ConvWeights::synthetic(l, &mut rng))
        .collect();
    let fc_w: Vec<f32> = rng.normal_vec(layers[2].m * 10).iter().map(|v| v * 0.1).collect();
    let fc_b: Vec<f32> = rng.normal_vec(10).iter().map(|v| v * 0.01).collect();

    let mut outs: Vec<(String, Vec<f32>)> = Vec::new();
    for a in &arts {
        let loaded = engine.load(&a.name).unwrap();
        let mut lits = vec![escoin::runtime::tensor_to_literal(&x).unwrap()];
        for wl in loaded.model_weight_literals(&convs, &fc_w, &fc_b).unwrap() {
            lits.push(wl);
        }
        let logits = loaded.execute(&lits).unwrap();
        assert_eq!(logits.len(), arts[0].batch * 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        outs.push((a.name.clone(), logits));
    }
    for pair in outs.windows(2) {
        let max_diff = pair[0]
            .1
            .iter()
            .zip(&pair[1].1)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-2,
            "{} vs {}: logits differ by {max_diff}",
            pair[0].0,
            pair[1].0
        );
    }
}

#[test]
fn engine_caches_compiled_executables() {
    let Some(engine) = engine() else { return };
    let a = engine.load("alexnet_conv3_sconv").unwrap();
    let b = engine.load("alexnet_conv3_sconv").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(engine) = engine() else { return };
    assert!(engine.load("no_such_artifact").is_err());
}
