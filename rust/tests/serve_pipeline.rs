//! The ISSUE's acceptance properties for the pipelined serving executor,
//! incremental replans, and DAG (branch-overlap) serving:
//!
//! * pipelined (`pipeline_depth = 2`) and sequential (`= 1`) serving
//!   produce **byte-identical** logits for the same request stream
//!   under a fixed plan — for the chain network (`minicnn`) *and* for
//!   an inception-structured graph network (`miniception`), whose
//!   slots run the asynchronous DAG walk;
//! * an incremental replan reuses the `Arc<LayerPlan>` pointers of
//!   untouched layers and compiles exactly one plan for a single
//!   router flip (pointer-equality + build-count asserted);
//! * `strict_replan` drains the pipeline before applying a replan and
//!   keeps answering every request.

use escoin::config::{miniception, minicnn};
use escoin::conv::{Method, PlanCache, WorkspaceArena};
use escoin::coordinator::{BatcherConfig, RouterConfig, ServerConfig, ServerHandle};
use escoin::util::{Rng, WorkerPool};
use std::sync::Arc;
use std::time::Duration;

/// A server config with replans and router exploration disabled, so the
/// per-layer methods — and therefore the exact floating-point program —
/// are identical regardless of pipelining.
fn fixed_plan_cfg(pipeline_depth: usize, batch_size: usize) -> ServerConfig {
    fixed_plan_cfg_for("minicnn", pipeline_depth, batch_size)
}

fn fixed_plan_cfg_for(network: &str, pipeline_depth: usize, batch_size: usize) -> ServerConfig {
    ServerConfig {
        network: network.into(),
        batcher: BatcherConfig {
            batch_size,
            max_wait: Duration::from_millis(2),
        },
        weight_seed: 77,
        threads: 3,
        router: RouterConfig {
            explore_every: 0,
            ..Default::default()
        },
        replan_every: 0,
        pipeline_depth,
        adaptive_tiling: false,
        ..Default::default()
    }
}

/// Serve `images` through a server and return the logits in submission
/// order.
fn serve_stream(cfg: ServerConfig, images: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let server = ServerHandle::start(cfg).expect("server start");
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();
    let logits: Vec<Vec<f32>> = pending
        .into_iter()
        .map(|rx| {
            rx.recv_timeout(Duration::from_secs(120))
                .expect("response")
                .expect("response ok")
                .logits
        })
        .collect();
    server.shutdown().expect("shutdown");
    logits
}

#[test]
fn pipelined_serving_is_byte_identical_to_sequential() {
    // minicnn's input layer is 3x16x16.
    let image_elems = 3 * 16 * 16;
    let mut rng = Rng::new(1234);
    let images: Vec<Vec<f32>> = (0..23).map(|_| rng.activation_vec(image_elems)).collect();

    let sequential = serve_stream(fixed_plan_cfg(1, 4), &images);
    let pipelined = serve_stream(fixed_plan_cfg(2, 4), &images);

    assert_eq!(sequential.len(), pipelined.len());
    for (i, (a, b)) in sequential.iter().zip(&pipelined).enumerate() {
        assert_eq!(a, b, "request {i}: pipelined logits diverged");
    }
}

#[test]
fn pipelined_serving_is_byte_identical_at_batch_one() {
    // Batch 1 is the latency-sensitive path the sub-quorum handshake
    // targets; pin its numerics too.
    let mut rng = Rng::new(4321);
    let images: Vec<Vec<f32>> = (0..9).map(|_| rng.activation_vec(3 * 16 * 16)).collect();
    let sequential = serve_stream(fixed_plan_cfg(1, 1), &images);
    let pipelined = serve_stream(fixed_plan_cfg(2, 1), &images);
    assert_eq!(sequential, pipelined);
}

#[test]
fn deeper_pipeline_depths_are_supported_and_correct() {
    // Depths beyond 2 are allowed (each slot gets an arena); answers
    // must stay correct and complete.
    let mut rng = Rng::new(99);
    let images: Vec<Vec<f32>> = (0..13).map(|_| rng.activation_vec(3 * 16 * 16)).collect();
    let want = serve_stream(fixed_plan_cfg(1, 4), &images);
    let got = serve_stream(fixed_plan_cfg(4, 4), &images);
    assert_eq!(want, got);
}

#[test]
fn dag_branch_overlap_composes_with_the_two_slot_pipeline() {
    // Serve an inception-structured graph network: each slot drives the
    // asynchronous DAG walk (branch jobs overlapping on the pool), and
    // the two-slot pipeline overlaps batches on top. Both compositions
    // must be byte-identical to sequential serving of the same stream,
    // and to the plan-level walk itself.
    let net = miniception();
    assert!(net.has_explicit_graph());
    let image_elems = 3 * 8 * 8; // miniception stem input
    let mut rng = Rng::new(2024);
    let images: Vec<Vec<f32>> = (0..19).map(|_| rng.activation_vec(image_elems)).collect();

    let sequential = serve_stream(fixed_plan_cfg_for("miniception", 1, 4), &images);
    let pipelined = serve_stream(fixed_plan_cfg_for("miniception", 2, 4), &images);
    assert_eq!(sequential.len(), pipelined.len());
    for (i, (a, b)) in sequential.iter().zip(&pipelined).enumerate() {
        assert_eq!(a, b, "request {i}: DAG + pipeline serving diverged");
    }

    // Oracle: at batch 1 with exploration off, the served logits must
    // equal the plan's own DAG walk under the default (heuristic)
    // method assignment — DirectSparse for these high-sparsity branch
    // convs, LoweredGemm for dense layers, which is what the plan
    // builder picks below.
    let b1 = serve_stream(fixed_plan_cfg_for("miniception", 2, 1), &images[..3]);
    let cache = PlanCache::build(&net, 77);
    let plan = cache.network_plan(&net, 1, |_, _| Method::DirectSparse);
    let pool = WorkerPool::new(3);
    let mut arena = WorkspaceArena::for_plan(&plan, &pool);
    for (img, served) in images[..3].iter().zip(&b1) {
        let want = plan.run_async(Some(img), &pool, &mut arena).to_vec();
        assert_eq!(served, &want, "served logits diverged from the DAG walk");
    }
}

#[test]
fn strict_replan_drains_the_pipeline_and_answers_everything() {
    // strict_replan = true with aggressive router churn: every request
    // must still be answered, answers stay within fp tolerance across
    // plan swaps, and replans still happen incrementally.
    let cfg = ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 13,
        threads: 2,
        router: RouterConfig {
            explore_every: 3,
            ..Default::default()
        },
        replan_every: 2,
        pipeline_depth: 2,
        strict_replan: true,
        adaptive_tiling: false,
        ..Default::default()
    };
    let server = ServerHandle::start(cfg).unwrap();
    let mut rng = Rng::new(15);
    let img = rng.activation_vec(server.image_elems());
    let first = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    for _ in 0..30 {
        let resp = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
        for (x, y) in resp.logits.iter().zip(&first.logits) {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs().max(x.abs()),
                "{x} vs {y} after strict replan"
            );
        }
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.snapshot.responses, 31);
    assert_eq!(stats.snapshot.errors, 0);
}

#[test]
fn incremental_replan_reuses_untouched_layer_plans() {
    let net = minicnn();
    let cache = PlanCache::build(&net, 42);
    let base = cache.network_plan(&net, 4, |_, _| Method::DirectSparse);
    let builds = cache.layer_builds();

    // Flip exactly one layer's method — the replanned network must
    // compile exactly one LayerPlan and keep every other Arc.
    let flipped = cache.network_plan(&net, 4, |name, _| {
        if name == "conv2" {
            Method::LoweredGemm
        } else {
            Method::DirectSparse
        }
    });
    assert_eq!(
        cache.layer_builds() - builds,
        1,
        "a single flip must rebuild exactly one layer plan"
    );

    let a = base.conv_plans();
    let b = flipped.conv_plans();
    assert_eq!(a.len(), b.len());
    for ((name_a, plan_a), (name_b, plan_b)) in a.iter().zip(b.iter()) {
        assert_eq!(name_a, name_b);
        if name_a == "conv2" {
            assert!(
                !Arc::ptr_eq(plan_a, plan_b),
                "the flipped layer must get a fresh plan"
            );
            assert_eq!(plan_b.method(), Method::LoweredGemm);
        } else {
            assert!(
                Arc::ptr_eq(plan_a, plan_b),
                "{name_a} was not flipped and must keep its cached Arc"
            );
        }
    }

    // Flipping back is free: the (layer, method) pair is cached.
    let back = cache.network_plan(&net, 4, |_, _| Method::DirectSparse);
    assert_eq!(cache.layer_builds() - builds, 1, "flip-back must be a cache hit");
    for ((_, plan_a), (_, plan_c)) in a.iter().zip(back.conv_plans().iter()) {
        assert!(Arc::ptr_eq(plan_a, plan_c));
    }
}

#[test]
fn server_replans_incrementally_under_router_churn() {
    // Force method churn with aggressive exploration and a tiny replan
    // cadence; the replan metrics must show that rebuilds stayed
    // incremental (bounded by the distinct (layer, method) pairs, far
    // below layers-per-replan), and answers must stay within fp
    // tolerance across plan swaps.
    let cfg = ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 13,
        threads: 2,
        router: RouterConfig {
            explore_every: 3,
            ..Default::default()
        },
        replan_every: 2,
        pipeline_depth: 2,
        adaptive_tiling: false,
        ..Default::default()
    };
    let server = ServerHandle::start(cfg).unwrap();
    let mut rng = Rng::new(14);
    let img = rng.activation_vec(server.image_elems());
    let first = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    for _ in 0..30 {
        let resp = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
        for (x, y) in resp.logits.iter().zip(&first.logits) {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs().max(x.abs()),
                "{x} vs {y} after replan"
            );
        }
    }
    let stats = server.shutdown().unwrap();
    let s = &stats.snapshot;
    assert_eq!(s.replans, stats.replans);
    if s.replans > 0 {
        // minicnn has 2 sparse conv layers and 3 usable methods, plus
        // the initial 3 builds — incremental replans can never compile
        // more than the distinct-(layer, method) universe.
        assert!(
            s.replan_layers_rebuilt <= 2 * 3,
            "replans rebuilt {} layer plans — not incremental",
            s.replan_layers_rebuilt
        );
    }
}

#[test]
fn adaptive_tiling_serving_is_byte_identical_to_pinned_tiling() {
    // Tile geometry is pure work-cutting: a server that retiles from
    // live telemetry at every replan checkpoint must answer with
    // exactly the bytes of a server whose tiling is pinned. Router
    // exploration is off so the method assignment cannot drift between
    // the two runs.
    let adaptive = |on: bool| ServerConfig {
        replan_every: 1,
        adaptive_tiling: on,
        ..fixed_plan_cfg(2, 2)
    };
    let mut rng = Rng::new(777);
    let images: Vec<Vec<f32>> = (0..17).map(|_| rng.activation_vec(3 * 16 * 16)).collect();
    let pinned = serve_stream(adaptive(false), &images);
    let retiled = serve_stream(adaptive(true), &images);
    assert_eq!(pinned, retiled, "a retile changed served logits");
}

#[test]
fn autotuned_serving_is_byte_identical_and_surfaces_the_gauge() {
    // The startup autotune sweep bakes simulator-ranked tile policies
    // before the first plan compiles. Geometry is pure work-cutting, so
    // a tuned server must answer with exactly the bytes of an untuned
    // one — and report how many layers it baked.
    let tuned_cfg = |on: bool| ServerConfig {
        autotune_policies: on,
        ..fixed_plan_cfg(2, 2)
    };
    let mut rng = Rng::new(4242);
    let images: Vec<Vec<f32>> = (0..11).map(|_| rng.activation_vec(3 * 16 * 16)).collect();

    let plain = serve_stream(tuned_cfg(false), &images);

    let server = ServerHandle::start(tuned_cfg(true)).expect("server start");
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();
    let tuned: Vec<Vec<f32>> = pending
        .into_iter()
        .map(|rx| {
            rx.recv_timeout(Duration::from_secs(120))
                .expect("response")
                .expect("response ok")
                .logits
        })
        .collect();
    let stats = server.shutdown().expect("shutdown");

    assert_eq!(plain, tuned, "autotuned policies changed served logits");
    // minicnn has 2 sparse conv layers; the sweep bakes both (the
    // provenance flips Default -> Tuned even when the winning geometry
    // matches the default).
    assert_eq!(stats.snapshot.tuned_layers, 2);
}
