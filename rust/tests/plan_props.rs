//! Property tests for the ExecutionPlan layer: every compiled plan must
//! match the Algorithm-1 oracle over the canonical shape grid × all four
//! methods (executed through worker pools of several sizes), whole-network
//! plans must be deterministic and allocation-stable against a shared
//! workspace arena, and pool runs must be byte-identical to
//! single-thread runs.

use escoin::config::{googlenet, miniception, minicnn, resnet50, ConvShape};
use escoin::conv::{
    direct_dense, shapes_under_test, winograd_applicable, ConvWeights, LayerPlan, Method,
    NetworkPlan, SparseLayout, TilePolicy, Workspace, WorkspaceArena, SIMD_LANES,
};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{Rng, WorkerPool};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn case(shape: &ConvShape, n: usize, seed: u64) -> (Tensor4, ConvWeights) {
    let mut rng = Rng::new(seed);
    let x = Tensor4::random_activations(Dims4::new(n, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(shape, &mut rng);
    (x, w)
}

/// Cross-method property: every `LayerPlan` output matches `direct_dense`
/// over the `shapes_under_test()` grid × all four `Method`s (Winograd —
/// now pool-parallel — where applicable), at several pool sizes and
/// batch sizes.
#[test]
fn property_every_layer_plan_matches_direct_dense() {
    let pools: Vec<WorkerPool> = [1, 2, 8].into_iter().map(WorkerPool::new).collect();
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        for batch in [1, 3] {
            let (x, w) = case(&shape, batch, 900 + i as u64);
            let want = direct_dense(&shape, &x, &w);
            for method in Method::ALL {
                if method == Method::Winograd && !winograd_applicable(&shape) {
                    continue;
                }
                let plan = LayerPlan::build(&shape, &w, method);
                for pool in &pools {
                    let got = plan.run(&x, pool);
                    assert!(
                        got.allclose(&want, 1e-3, 1e-4),
                        "{shape} under {} (t{}, b{batch})",
                        method.name(),
                        pool.workers()
                    );
                }
            }
        }
    }
}

/// Pool-size invariance: for every method (including the newly
/// parallelised Winograd path), executing one compiled plan through
/// pools of different sizes produces **byte-identical** output — tile
/// decomposition is fixed by the plan, never by the worker count.
#[test]
fn property_plan_output_is_byte_identical_across_pool_sizes() {
    let single = WorkerPool::new(1);
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 3, 2100 + i as u64);
        for method in Method::ALL {
            if method == Method::Winograd && !winograd_applicable(&shape) {
                continue;
            }
            let plan = LayerPlan::build(&shape, &w, method);
            let reference = plan.run(&x, &single);
            let ref_bits: Vec<u32> = reference.data().iter().map(|v| v.to_bits()).collect();
            for threads in [2, 4, 16] {
                let pool = WorkerPool::new(threads);
                let got = plan.run(&x, &pool);
                let got_bits: Vec<u32> = got.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    ref_bits,
                    got_bits,
                    "{shape} under {} t{threads} diverged from single-thread",
                    method.name()
                );
            }
        }
    }
}

/// The tentpole acceptance grid: the cache-blocked multi-channel
/// microkernel must be **byte-identical** to the unblocked per-channel
/// kernel (the PR-2 oracle, `TilePolicy::unblocked()`) on every shape
/// of the canonical grid, across pool sizes 1/4/8 and a spread of
/// `TilePolicy` settings — tile count, register-block width, and row
/// block length are pure geometry and must never touch a result bit.
#[test]
fn property_blocked_microkernel_is_byte_identical_across_policies_and_pools() {
    // `lanes` is pinned to 1 throughout: this grid is the SCALAR
    // byte-identity contract (the vectorized kernel is deliberately a
    // different op order — its own grid below is ULP-bounded). The
    // pinning keeps this test meaningful under `--features simd`, where
    // `TilePolicy::default()` flips to vector lanes.
    let policies = [
        TilePolicy {
            lanes: 1,
            layout: SparseLayout::Csr,
            ..TilePolicy::default()
        },
        TilePolicy {
            target_tiles: 3,
            mr: 2,
            block_floats: 64,
            lanes: 1,
            layout: SparseLayout::Csr,
        },
        TilePolicy {
            target_tiles: 7,
            mr: 8,
            block_floats: 33,
            lanes: 1,
            layout: SparseLayout::Csr,
        },
        TilePolicy {
            target_tiles: 512,
            mr: 3,
            block_floats: 1,
            lanes: 1,
            layout: SparseLayout::Csr,
        },
    ];
    let pools: Vec<WorkerPool> = [1, 4, 8].into_iter().map(WorkerPool::new).collect();
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 3, 3100 + i as u64);
        // Oracle: the unblocked per-channel kernel (mr = 1, one pass
        // over the whole span — the exact PR-2 `sconv_plane` loop),
        // single worker.
        let oracle_plan =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, TilePolicy::unblocked());
        let oracle = bits(oracle_plan.run(&x, &pools[0]).data());
        for policy in policies {
            let plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
            assert_eq!(plan.tile_policy(), Some(policy));
            for pool in &pools {
                let got = bits(plan.run(&x, pool).data());
                assert_eq!(
                    oracle,
                    got,
                    "{shape} diverged from the per-channel oracle under {policy:?} t{}",
                    pool.workers()
                );
            }
        }
    }
}

/// Monotonic-key ULP distance: maps each float's bit pattern onto a
/// number line where adjacent representable floats differ by 1, so the
/// distance is order-of-magnitude aware (unlike an absolute epsilon).
fn ulps(a: f32, b: f32) -> u64 {
    fn key(x: f32) -> i64 {
        let i = x.to_bits() as i32 as i64;
        if i < 0 {
            (i32::MIN as i64) - i
        } else {
            i
        }
    }
    key(a).abs_diff(key(b))
}

/// The vectorized-microkernel acceptance grid (the tentpole's
/// correctness contract), cfg-independent — the policies name their
/// lane width explicitly, so this exercises the vector kernels even in
/// the default (scalar-default) build:
///
/// * the SIMD plan is **byte-identical to itself** across pool sizes
///   1/4/8 (per-element op order is fixed by CSR order, not by the
///   strip/tile/pool decomposition);
/// * the bank-balanced plan is **byte-identical** to the SIMD-CSR plan
///   (padding slots are arithmetic no-ops);
/// * both are ULP-bounded against the scalar byte-determinism oracle
///   (the lane order reassociates the 4-wide-grouped scalar sums).
#[test]
fn property_vectorized_plans_are_pool_invariant_and_ulp_close_to_scalar() {
    let pools: Vec<WorkerPool> = [1, 4, 8].into_iter().map(WorkerPool::new).collect();
    let scalar_policy = TilePolicy {
        lanes: 1,
        layout: SparseLayout::Csr,
        ..TilePolicy::default()
    };
    let simd_policy = TilePolicy {
        lanes: SIMD_LANES,
        layout: SparseLayout::Csr,
        ..TilePolicy::default()
    };
    let balanced_policy = TilePolicy {
        lanes: SIMD_LANES,
        layout: SparseLayout::Balanced,
        ..TilePolicy::default()
    };
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 2, 4400 + i as u64);
        let scalar_plan =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, scalar_policy);
        let simd_plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, simd_policy);
        let balanced_plan =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, balanced_policy);

        let scalar = scalar_plan.run(&x, &pools[0]);
        let simd_ref = bits(simd_plan.run(&x, &pools[0]).data());
        let bal_ref = bits(balanced_plan.run(&x, &pools[0]).data());
        assert_eq!(
            simd_ref, bal_ref,
            "{shape}: balanced layout diverged from the CSR vector kernel"
        );
        for pool in &pools[1..] {
            assert_eq!(
                simd_ref,
                bits(simd_plan.run(&x, pool).data()),
                "{shape}: simd plan not pool-invariant at t{}",
                pool.workers()
            );
            assert_eq!(
                bal_ref,
                bits(balanced_plan.run(&x, pool).data()),
                "{shape}: balanced plan not pool-invariant at t{}",
                pool.workers()
            );
        }
        for (j, (&s, &v)) in scalar
            .data()
            .iter()
            .zip(simd_plan.run(&x, &pools[0]).data())
            .enumerate()
        {
            assert!(
                ulps(s, v) <= 256 || (s - v).abs() <= 1e-4,
                "{shape} elem {j}: scalar {s} vs simd {v} ({} ulps)",
                ulps(s, v)
            );
        }
    }
}

/// The blocked microkernel through the **async tile body** (the DAG
/// executor's path): driving `run_async_tile` by hand under non-default
/// policies must still reproduce the blocking `execute_into` bytes.
#[test]
fn property_async_tile_body_honours_tile_policies() {
    use escoin::conv::ConvExecutor;
    use escoin::util::SharedSlice;
    let pool = WorkerPool::new(3);
    let policies = [
        TilePolicy::unblocked(),
        TilePolicy {
            target_tiles: 5,
            mr: 3,
            block_floats: 48,
            lanes: 1,
            layout: SparseLayout::Csr,
        },
        // The vectorized kernel through the same async body: the
        // blocking/async agreement must hold for every lane width and
        // layout, not just the scalar oracle.
        TilePolicy {
            target_tiles: 5,
            mr: 4,
            block_floats: 48,
            lanes: SIMD_LANES,
            layout: SparseLayout::Balanced,
        },
    ];
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 2, 3600 + i as u64);
        for policy in policies {
            let plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
            let mut ws = Workspace::new();
            let mut want = Tensor4::zeros(plan.out_dims(2));
            plan.execute_into(2, x.data(), &pool, &mut ws, want.data_mut(), None);

            let padded = x.pad_spatial(shape.pad);
            let padded: &[f32] = if shape.pad > 0 { padded.data() } else { x.data() };
            let plen = if shape.pad > 0 {
                2 * shape.c * shape.padded_h() * shape.padded_w()
            } else {
                0
            };
            let scratch_len = plan.workspace_floats(2, 1) - plen;
            let mut scratch = vec![0.0f32; scratch_len];
            let mut got = vec![f32::NAN; want.data().len()];
            {
                let out_sh = SharedSlice::new(&mut got);
                let scr_sh = SharedSlice::new(&mut scratch);
                for t in 0..plan.async_tiles(2) {
                    // SAFETY: one worker, exclusive buffers.
                    unsafe { plan.run_async_tile(t, 0, 2, padded, &scr_sh, &out_sh) };
                }
            }
            assert_eq!(
                bits(want.data()),
                bits(&got),
                "{shape} async tiles diverged under {policy:?}"
            );
        }
    }
}

/// Plan execution against a shared, reused workspace must equal the
/// fresh-workspace result bit for bit (no scratch contamination).
#[test]
fn property_shared_workspace_is_bit_stable() {
    let pool = WorkerPool::new(3);
    let mut ws = Workspace::new(); // shared across shapes AND methods
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 2, 1300 + i as u64);
        for method in [Method::DirectSparse, Method::LoweredGemm, Method::LoweredSpmm] {
            let plan = LayerPlan::build(&shape, &w, method);
            let fresh = plan.run(&x, &pool);
            let mut out = Tensor4::zeros(plan.out_dims(2));
            plan.execute_into(2, x.data(), &pool, &mut ws, out.data_mut(), None);
            assert_eq!(
                out.data(),
                fresh.data(),
                "{shape} under {}",
                method.name()
            );
        }
    }
}

/// Determinism: two `NetworkPlan::run` calls on one shared
/// `WorkspaceArena` produce byte-identical outputs (catches
/// workspace-reuse contamination), the arena does not grow after the
/// first run (zero steady-state allocation), and a single-thread pool
/// reproduces the multi-worker bytes on the same arena.
#[test]
fn network_plan_runs_on_shared_arena_are_byte_identical() {
    let net = minicnn();
    let pool = WorkerPool::new(2);
    let single = WorkerPool::new(1);
    for method in [Method::DirectSparse, Method::LoweredSpmm, Method::LoweredGemm] {
        let plan = NetworkPlan::build(&net, 3, 0xDE, |_, _| method);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let first = plan.run(&pool, &mut arena).to_vec();
        let floats_after_first = arena.total_floats();
        let second = plan.run(&pool, &mut arena).to_vec();
        let first_bits: Vec<u32> = first.iter().map(|v| v.to_bits()).collect();
        let second_bits: Vec<u32> = second.iter().map(|v| v.to_bits()).collect();
        assert_eq!(first_bits, second_bits, "{}", method.name());
        assert_eq!(
            arena.total_floats(),
            floats_after_first,
            "arena grew in steady state ({})",
            method.name()
        );
        // Same arena, single-thread pool: still the same bytes.
        let serial = plan.run(&single, &mut arena).to_vec();
        let serial_bits: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            first_bits,
            serial_bits,
            "single-thread run diverged ({})",
            method.name()
        );
    }
}

/// DAG-vs-sequential equivalence on the small inception graph, swept
/// wide: external and synthetic inputs, batch 2, pool sizes 1/4/8 —
/// the asynchronous branch-overlap walk must reproduce the sequential
/// topological walk **byte for byte**.
#[test]
fn miniception_dag_walk_is_byte_identical_to_sequential_across_pools() {
    let net = miniception();
    let plan = NetworkPlan::build(&net, 2, 0x5EED, |_, _| Method::DirectSparse);
    assert!(plan.supports_async());
    let single = WorkerPool::new(1);
    let mut arena = WorkspaceArena::for_plan(&plan, &single);
    let mut rng = Rng::new(4);
    let mut img = vec![0.0; plan.input_dims().len()];
    rng.fill_activations(&mut img);
    let seq_ext = bits(plan.run_with_input(&img, &single, &mut arena));
    let seq_syn = bits(plan.run(&single, &mut arena));
    for threads in [1, 4, 8] {
        let pool = WorkerPool::new(threads);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let got_ext = bits(plan.run_async(Some(&img), &pool, &mut arena));
        assert_eq!(seq_ext, got_ext, "external input diverged at t{threads}");
        let got_syn = bits(plan.run_async(None, &pool, &mut arena));
        assert_eq!(seq_syn, got_syn, "synthetic input diverged at t{threads}");
    }
}

/// The acceptance property on the real workload: `googlenet()`'s
/// inception modules execute as a branch/merge DAG whose async walk is
/// byte-identical to the sequential walk at pool sizes 1, 4, and 8.
/// One batch-1 sequential reference, three async runs — the full
/// network each time, so this is the suite's heaviest test.
#[test]
fn googlenet_dag_walk_matches_sequential_walk_at_pools_1_4_8() {
    let net = googlenet();
    let plan = NetworkPlan::build(&net, 1, 0x6006, |_, _| Method::DirectSparse);
    assert!(plan.supports_async(), "googlenet must compile to a DAG plan");
    let ref_pool = WorkerPool::new(4);
    let mut arena = WorkspaceArena::for_plan(&plan, &ref_pool);
    let sequential = bits(plan.run(&ref_pool, &mut arena));
    drop(arena);
    for threads in [1, 4, 8] {
        let pool = WorkerPool::new(threads);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let dag = bits(plan.run_async(None, &pool, &mut arena));
        assert_eq!(
            sequential, dag,
            "googlenet DAG walk diverged from the sequential walk at t{threads}"
        );
    }
}

/// The residual counterpart of the GoogLeNet property: `resnet50()` is
/// now a branch/merge graph (bottleneck main paths + shortcut edges
/// joined by Add merges, including every stride-2 and downsample conv
/// on the strided blocked microkernel), and its async DAG walk must be
/// byte-identical to the sequential walk at every pool size.
#[test]
fn resnet50_dag_walk_matches_sequential_walk_at_pools_1_4_8() {
    let net = resnet50();
    let plan = NetworkPlan::build(&net, 1, 0x6007, |_, _| Method::DirectSparse);
    assert!(plan.supports_async(), "resnet50 must compile to a DAG plan");
    let ref_pool = WorkerPool::new(4);
    let mut arena = WorkspaceArena::for_plan(&plan, &ref_pool);
    let sequential = bits(plan.run(&ref_pool, &mut arena));
    drop(arena);
    for threads in [1, 4, 8] {
        let pool = WorkerPool::new(threads);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let dag = bits(plan.run_async(None, &pool, &mut arena));
        assert_eq!(
            sequential, dag,
            "resnet50 DAG walk diverged from the sequential walk at t{threads}"
        );
    }
}

/// The same arena must be safely shareable across *different* plans
/// (method switches on replan): outputs still match a fresh arena.
#[test]
fn arena_survives_method_switches() {
    let net = minicnn();
    let pool = WorkerPool::new(2);
    let mut shared = WorkspaceArena::new();
    let mut rng = Rng::new(42);
    let gemm = NetworkPlan::build(&net, 2, 5, |_, _| Method::LoweredGemm);
    let sparse = NetworkPlan::build(&net, 2, 5, |_, _| Method::DirectSparse);
    let img = {
        let mut v = vec![0.0; gemm.input_dims().len()];
        rng.fill_activations(&mut v);
        v
    };
    for plan in [&gemm, &sparse, &gemm, &sparse] {
        let mut fresh = WorkspaceArena::for_plan(plan, &pool);
        let want = plan.run_with_input(&img, &pool, &mut fresh).to_vec();
        let got = plan.run_with_input(&img, &pool, &mut shared).to_vec();
        assert_eq!(got, want);
    }
    // Both plans see the same weights (same seed), so their outputs agree
    // numerically too.
    let mut a = WorkspaceArena::for_plan(&gemm, &pool);
    let mut b = WorkspaceArena::for_plan(&sparse, &pool);
    let ya = gemm.run_with_input(&img, &pool, &mut a).to_vec();
    let yb = sparse.run_with_input(&img, &pool, &mut b).to_vec();
    for (x, y) in ya.iter().zip(&yb) {
        assert!((x - y).abs() <= 1e-3 + 1e-3 * y.abs().max(x.abs()), "{x} vs {y}");
    }
}
