//! Property tests for the simulator-guided TilePolicy autotuner
//! (`simulator::autotune`): the sweep is a pure function of `(shape,
//! weights, hierarchy)`, every geometry it may pick preserves results
//! (byte-identical scalar, ULP-bounded vectorized — the same contract
//! `tests/plan_props.rs` pins for hand-picked policies), and a tuned
//! policy is reachable end to end through the public `PlanCache` API.

use escoin::config::{minicnn, ConvShape, LayerKind};
use escoin::conv::{
    shapes_under_test, ConvWeights, LayerPlan, Method, PlanCache, PolicySource, SparseLayout,
    TilePolicy, SIMD_LANES,
};
use escoin::simulator::{autotune_policy, candidate_policies, tune_plan_cache, P100_GEOMETRY};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{Rng, WorkerPool};

fn case(shape: &ConvShape, batch: usize, seed: u64) -> (Tensor4, ConvWeights) {
    let mut rng = Rng::new(seed);
    let x = Tensor4::random_activations(Dims4::new(batch, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(shape, &mut rng);
    (x, w)
}

fn bits(t: &Tensor4) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Monotonic-key ULP distance (same mapping as `tests/plan_props.rs`).
fn ulps(a: f32, b: f32) -> u64 {
    fn key(x: f32) -> i64 {
        let i = x.to_bits() as i32 as i64;
        if i < 0 {
            (i32::MIN as i64) - i
        } else {
            i
        }
    }
    key(a).abs_diff(key(b))
}

/// Determinism over the canonical shape grid: the same `(shape,
/// weights, hierarchy)` always yields the identical ranking and winner,
/// and the ranking covers exactly the fixed candidate list.
#[test]
fn property_sweep_is_deterministic_over_the_shape_grid() {
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (_, w) = case(&shape, 1, 6000 + i as u64);
        let a = autotune_policy(&shape, &w, P100_GEOMETRY);
        let b = autotune_policy(&shape, &w, P100_GEOMETRY);
        assert_eq!(a.best, b.best, "{shape}: winner is not deterministic");
        assert_eq!(a.ranked.len(), candidate_policies().len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.policy, y.policy, "{shape}: ranking order drifted");
            assert_eq!(x.rank_key(), y.rank_key());
        }
        // Sorted best-first, with the default always present as the
        // predicted-vs-measured baseline.
        assert_eq!(a.best, a.ranked[0].policy);
        for pair in a.ranked.windows(2) {
            assert!(pair[0].rank_key() <= pair[1].rank_key());
        }
        assert!(
            a.ranked[0].report.dram_bytes <= a.default_score().report.dram_bytes,
            "{shape}: winner predicts more DRAM traffic than the default"
        );
    }
}

/// The safety property that makes offline tuning unconditionally safe
/// to bake: ANY policy the sweep may pick preserves results. Scalar
/// candidates are byte-identical to the scalar reference; vectorized
/// candidates (their own deliberate op order) stay within the crate's
/// ULP envelope — across pools 1/4/8, on every grid shape. The swept
/// winner itself is checked on top of the full candidate list.
#[test]
fn property_every_swept_policy_preserves_results() {
    let scalar_ref = TilePolicy {
        lanes: 1,
        layout: SparseLayout::Csr,
        ..TilePolicy::default()
    };
    // The fixed candidates (lanes follow the build default) plus forced
    // vector/balanced candidates, so the default CI leg also exercises
    // the ULP arm and the simd leg also exercises the scalar arm.
    let mut policies = candidate_policies();
    policies.push(TilePolicy {
        lanes: SIMD_LANES,
        ..scalar_ref
    });
    policies.push(TilePolicy {
        lanes: SIMD_LANES,
        layout: SparseLayout::Balanced,
        ..scalar_ref
    });
    policies.push(scalar_ref);

    let pools: Vec<WorkerPool> = [1usize, 4, 8].into_iter().map(WorkerPool::new).collect();
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 2, 6300 + i as u64);
        let reference = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, scalar_ref)
            .run(&x, &pools[0]);
        let ref_bits = bits(&reference);
        let mut swept = policies.clone();
        swept.push(autotune_policy(&shape, &w, P100_GEOMETRY).best);
        for policy in swept {
            let plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
            let single = plan.run(&x, &pools[0]);
            for pool in &pools[1..] {
                assert_eq!(
                    bits(&single),
                    bits(&plan.run(&x, pool)),
                    "{shape} with {policy:?}: pool size changed bytes"
                );
            }
            if policy.lanes <= 1 {
                assert_eq!(
                    ref_bits,
                    bits(&single),
                    "{shape} with scalar {policy:?}: bytes diverged from the reference"
                );
            } else {
                for (j, (&s, &v)) in reference.data().iter().zip(single.data()).enumerate() {
                    assert!(
                        ulps(s, v) <= 256 || (s - v).abs() <= 1e-4,
                        "{shape} with {policy:?} elem {j}: scalar {s} vs vector {v} ({} ulps)",
                        ulps(s, v)
                    );
                }
            }
        }
    }
}

/// End-to-end reachability through the public API: `tune_plan_cache`
/// bakes the sweep winner into the `PlanCache`, the compiled plan
/// reports `PolicySource::Tuned` with the winning geometry, results
/// don't move, and two independently built caches tune to identical
/// policies (cross-cache determinism).
#[test]
fn tuned_policies_are_baked_deterministically_through_the_plan_cache() {
    let net = minicnn();
    let cache = PlanCache::build(&net, 9);
    let twin = PlanCache::build(&net, 9);
    let pool = WorkerPool::new(4);

    let sparse: Vec<(&str, &ConvShape)> = net
        .layers
        .iter()
        .filter_map(|l| match &l.kind {
            LayerKind::Conv(s) if s.is_sparse() => Some((l.name.as_str(), s)),
            _ => None,
        })
        .collect();
    assert!(!sparse.is_empty(), "minicnn must have sparse conv layers");

    // Outputs of the pre-tune plans, per sparse layer.
    let before: Vec<Tensor4> = sparse
        .iter()
        .map(|(name, shape)| {
            let (x, _) = case(shape, 2, 7000);
            cache.plan_for(name, shape, Method::DirectSparse).run(&x, &pool)
        })
        .collect();

    let tuned = tune_plan_cache(&cache, &net, P100_GEOMETRY);
    assert_eq!(tuned, sparse.len(), "every sparse layer gets a bake");
    assert_eq!(tune_plan_cache(&cache, &net, P100_GEOMETRY), 0, "idempotent");
    tune_plan_cache(&twin, &net, P100_GEOMETRY);

    let default_lanes = TilePolicy::default().lanes;
    for (i, (name, shape)) in sparse.iter().enumerate() {
        // The baked policy is exactly the sweep winner, on both caches.
        let want = autotune_policy(shape, cache.conv_weights(name).unwrap(), P100_GEOMETRY).best;
        assert_eq!(cache.tile_policy(name), want);
        assert_eq!(twin.tile_policy(name), want, "{name}: caches disagree");
        assert_eq!(cache.tile_policy_source(name), PolicySource::Tuned);

        // The recompiled plan carries the tuned geometry + provenance...
        let plan = cache.plan_for(name, shape, Method::DirectSparse);
        assert_eq!(plan.policy_source(), PolicySource::Tuned);
        assert_eq!(plan.tile_policy(), Some(want));

        // ...and moves no result: candidates keep the build's default
        // lanes, so tuned output is byte-identical to the pre-tune
        // output (same op order), on every build leg.
        assert_eq!(want.lanes, default_lanes);
        let (x, _) = case(shape, 2, 7000);
        assert_eq!(
            bits(&before[i]),
            bits(&plan.run(&x, &pool)),
            "{name}: tuning changed served bytes"
        );
    }
}
