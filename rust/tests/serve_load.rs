//! The ISSUE's acceptance properties for the multi-tenant SLO-aware
//! serving front door and the deterministic closed-loop load generator:
//!
//! * **Determinism** — the load generator is a pure function of its
//!   seed: the virtual arrival schedule is byte-equal across calls, and
//!   two closed-loop runs against identical fixed-plan servers produce
//!   identical per-request method traces;
//! * **Isolation** — two tenants co-served behind one front door (one
//!   worker pool, interleaved pipeline slots) answer byte-identically
//!   to each tenant served alone;
//! * **Pressure routing** — a saturated admission queue flips the
//!   routers to the deterministic cheapest-method assignment, and the
//!   server recovers (pressure released, counters balanced) once the
//!   backlog drains;
//! * **Deadline shedding** — an already-expired request is answered
//!   with the typed `DeadlineExpired` error at batch formation, never
//!   occupies a pipeline slot, and leaves co-served logits
//!   byte-identical;
//!
//! each at pool sizes 1, 4, and 8.

use escoin::bench_harness::{run_load, schedule, LoadGenConfig};
use escoin::coordinator::{
    BatcherConfig, InferResponse, Method, RouterConfig, ServerConfig, ServerError, ServerHandle,
};
use escoin::util::Rng;
use std::time::{Duration, Instant};

/// A two-tenant server config with replans, exploration, and adaptive
/// tiling disabled, so the method assignment — and therefore the exact
/// floating-point program — cannot drift between runs.
fn fixed_plan_cfg(network: &str, tenants: &[&str], threads: usize, batch: usize) -> ServerConfig {
    ServerConfig {
        network: network.into(),
        tenants: tenants.iter().map(|t| t.to_string()).collect(),
        batcher: BatcherConfig {
            batch_size: batch,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 77,
        threads,
        router: RouterConfig {
            explore_every: 0,
            ..Default::default()
        },
        replan_every: 0,
        adaptive_tiling: false,
        ..Default::default()
    }
}

#[test]
fn same_seed_yields_identical_schedule_and_method_trace() {
    let gen = LoadGenConfig {
        seed: 0xD5EED,
        requests: 40,
        mean_interarrival: Duration::from_micros(100),
        tenant_weights: vec![2, 1],
        deadline: None,
        window: 6,
    };
    // The arrival schedule is a pure function of the config.
    let sched = schedule(&gen);
    assert_eq!(sched, schedule(&gen));

    for threads in [1, 4, 8] {
        let run = || {
            let server =
                ServerHandle::start(fixed_plan_cfg("minicnn", &["microcnn"], threads, 2)).unwrap();
            let report = run_load(&server, &gen).unwrap();
            server.shutdown().unwrap();
            report
        };
        let a = run();
        let b = run();
        assert_eq!(a.submitted, gen.requests, "t{threads}");
        assert_eq!(a.rejected, 0, "t{threads}: unbounded queue rejected");
        assert_eq!(a.completed, gen.requests, "t{threads}");
        // The trace covers every arrival, in arrival order, against the
        // tenant the schedule picked, with a non-trivial method vector.
        assert_eq!(a.method_trace.len(), sched.len(), "t{threads}");
        for ((idx, tenant, methods), (i, arr)) in a.method_trace.iter().zip(sched.iter().enumerate())
        {
            assert_eq!(*idx, i, "t{threads}: trace out of arrival order");
            assert_eq!(*tenant, arr.tenant, "t{threads}: tenant diverged");
            assert!(!methods.is_empty(), "t{threads}: empty method vector");
        }
        // Same seed, same config, fresh server: identical trace.
        assert_eq!(a.method_trace, b.method_trace, "t{threads}");
    }
}

#[test]
fn co_served_tenants_answer_byte_identically_to_solo_serving() {
    let nreq = 8usize;
    for threads in [1, 4, 8] {
        // Per-tenant request streams, keyed by index so solo and
        // co-served runs submit exactly the same images.
        let mut rng = Rng::new(640 + threads as u64);
        let mini_imgs: Vec<Vec<f32>> = (0..nreq).map(|_| rng.activation_vec(3 * 16 * 16)).collect();
        let micro_imgs: Vec<Vec<f32>> = (0..nreq).map(|_| rng.activation_vec(3 * 8 * 8)).collect();

        let solo = |network: &str, images: &[Vec<f32>]| -> Vec<Vec<f32>> {
            let server = ServerHandle::start(fixed_plan_cfg(network, &[], threads, 1)).unwrap();
            let pending: Vec<_> = images
                .iter()
                .map(|img| server.submit(img.clone()).unwrap())
                .collect();
            let logits = pending
                .into_iter()
                .map(|rx| {
                    rx.recv_timeout(Duration::from_secs(120))
                        .expect("solo response")
                        .expect("solo ok")
                        .logits
                })
                .collect();
            server.shutdown().unwrap();
            logits
        };
        let mini_solo = solo("minicnn", &mini_imgs);
        let micro_solo = solo("microcnn", &micro_imgs);

        // Co-serve the interleaved streams through one front door: one
        // shared pool, pipeline slots mixing both tenants in flight.
        let server =
            ServerHandle::start(fixed_plan_cfg("minicnn", &["microcnn"], threads, 1)).unwrap();
        let pending: Vec<(usize, _)> = (0..nreq)
            .flat_map(|i| {
                [
                    (0usize, server.submit_to(0, mini_imgs[i].clone(), None).unwrap()),
                    (1usize, server.submit_to(1, micro_imgs[i].clone(), None).unwrap()),
                ]
            })
            .collect();
        let mut co: Vec<Vec<Vec<f32>>> = vec![Vec::new(), Vec::new()];
        for (tenant, rx) in pending {
            co[tenant].push(
                rx.recv_timeout(Duration::from_secs(120))
                    .expect("co-served response")
                    .expect("co-served ok")
                    .logits,
            );
        }
        server.shutdown().unwrap();

        assert_eq!(co[0], mini_solo, "t{threads}: minicnn logits diverged");
        assert_eq!(co[1], micro_solo, "t{threads}: microcnn logits diverged");
    }
}

/// A request whose deadline has already expired when its batch is
/// staged is shed with the typed [`ServerError::DeadlineExpired`] —
/// counted (`deadline_shed`), never an `error`, never occupying a
/// pipeline slot — and the co-served healthy stream's logits are
/// byte-identical to a run with no shed request at all.
#[test]
fn expired_deadline_requests_are_shed_with_typed_error() {
    for threads in [1, 4, 8] {
        let mut rng = Rng::new(900 + threads as u64);
        let imgs: Vec<Vec<f32>> = (0..6).map(|_| rng.activation_vec(3 * 16 * 16)).collect();
        let doomed: Vec<f32> = rng.activation_vec(3 * 16 * 16);
        let serve_all = |server: &ServerHandle| -> Vec<Vec<f32>> {
            imgs.iter()
                .map(|img| {
                    server
                        .submit(img.clone())
                        .unwrap()
                        .recv()
                        .expect("channel")
                        .expect("healthy response")
                        .logits
                })
                .collect()
        };

        // Baseline: the healthy stream alone.
        let server = ServerHandle::start(fixed_plan_cfg("minicnn", &[], threads, 1)).unwrap();
        let baseline = serve_all(&server);
        server.shutdown().unwrap();

        // Mixed: an already-expired request rides ahead of the same
        // stream. It must be answered typed, before any pool work.
        let server = ServerHandle::start(fixed_plan_cfg("minicnn", &[], threads, 1)).unwrap();
        let expired = Instant::now() - Duration::from_secs(1);
        let rx = server.submit_to(0, doomed.clone(), Some(expired)).unwrap();
        match rx.recv().expect("shed response channel") {
            Err(ServerError::DeadlineExpired) => {}
            other => panic!("t{threads}: expected DeadlineExpired, got {other:?}"),
        }
        let mixed = serve_all(&server);
        let stats = server.shutdown().unwrap();

        assert_eq!(
            mixed, baseline,
            "t{threads}: shed request perturbed co-served logits"
        );
        assert_eq!(stats.snapshot.deadline_shed, 1, "t{threads}");
        // Shedding is a typed outcome, not a server error, and the shed
        // request never became a response.
        assert_eq!(stats.snapshot.errors, 0, "t{threads}");
        assert_eq!(stats.snapshot.responses, imgs.len() as u64, "t{threads}");
        assert_eq!(stats.snapshot.rejected, 0, "t{threads}");
    }
}

#[test]
fn saturation_flips_methods_to_cheapest_and_recovers() {
    fn method_of(resp: &InferResponse, layer: &str) -> Method {
        resp.methods
            .iter()
            .find(|(n, _)| n == layer)
            .unwrap_or_else(|| panic!("no conv layer {layer} in response"))
            .1
    }
    for threads in [1, 4, 8] {
        // sparsity_threshold 0.95 puts minicnn's sparse convs (0.7 /
        // 0.8) below the static heuristic's sparse cutoff, so the calm
        // assignment is LoweredGemm — provably different from the
        // pressure assignment (cheapest = DirectSparse, which pays no
        // im2col materialization).
        let cfg = ServerConfig {
            network: "minicnn".into(),
            batcher: BatcherConfig {
                batch_size: 1,
                max_wait: Duration::from_millis(1),
            },
            weight_seed: 77,
            threads,
            router: RouterConfig {
                explore_every: 0,
                sparsity_threshold: 0.95,
                pressure_queue_depth: 2,
                ..Default::default()
            },
            replan_every: 0,
            adaptive_tiling: false,
            ..Default::default()
        };
        let server = ServerHandle::start(cfg).unwrap();
        let mut rng = Rng::new(7);
        let img = rng.activation_vec(server.image_elems());

        // Calm: one request at a time stays below the depth trigger and
        // serves under the static (raised-threshold) assignment.
        let calm = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
        assert_eq!(method_of(&calm, "conv2"), Method::LoweredGemm, "t{threads}");
        assert_eq!(method_of(&calm, "conv3"), Method::LoweredGemm, "t{threads}");

        // Saturate: a 24-request burst holds the admitted depth above
        // the threshold for most of the drain, so the pressure replan
        // must serve some of it under the cheapest assignment.
        let pending: Vec<_> = (0..24).map(|_| server.submit(img.clone()).unwrap()).collect();
        let responses: Vec<InferResponse> = pending
            .into_iter()
            .map(|rx| {
                rx.recv_timeout(Duration::from_secs(120))
                    .expect("burst response")
                    .expect("burst ok")
            })
            .collect();
        let pressured = responses
            .iter()
            .filter(|r| {
                method_of(r, "conv2") == Method::DirectSparse
                    && method_of(r, "conv3") == Method::DirectSparse
            })
            .count();
        assert!(
            pressured > 0,
            "t{threads}: saturation never flipped routing to cheapest"
        );

        // Recover: the backlog has drained, so pressure releases before
        // the next request is staged; the flip is visible in balanced
        // enter/exit counters and a cleared gauge, and serving goes on.
        let after = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
        assert_eq!(after.logits.len(), server.num_classes());
        let m = server.metrics();
        assert!(m.pressure_enters >= 1, "t{threads}: pressure never engaged");
        assert_eq!(
            m.pressure_enters, m.pressure_exits,
            "t{threads}: pressure did not release"
        );
        assert!(!m.pressure_mode, "t{threads}: gauge still set");
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.snapshot.errors, 0, "t{threads}");
        assert_eq!(stats.snapshot.responses, 26, "t{threads}");
        assert_eq!(stats.snapshot.rejected, 0, "t{threads}");
    }
}
