//! Cross-module integration tests that need no AOT artifacts: network
//! tables -> pruning -> all native conv methods -> scheduler -> harness.

use escoin::bench_harness::fig10::{fig10_cache_rates, Fig10Opts};
use escoin::config::{all_networks, network_by_name, ConvShape};
use escoin::conv::{
    direct_dense, lowered_gemm, lowered_spmm, sconv, sconv_ell, winograd_3x3,
    winograd_applicable, ConvWeights,
};
use escoin::coordinator::{Method, NetworkSchedule, Router, RouterConfig};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{Rng, WorkerPool};
use std::sync::Arc;

/// Every sparse CONV layer of every network, scaled down, run through all
/// applicable methods and cross-checked — the whole-repo correctness net.
#[test]
fn all_network_sparse_layers_agree_across_methods() {
    for net in all_networks() {
        for (name, shape) in net.sparse_conv_layers() {
            // Scale to keep runtime sane; structure (filter, stride, pad,
            // groups, sparsity) is preserved.
            let shape: ConvShape = {
                let mut s = shape.scaled_spatial(4);
                // channel-scale too: keep it small but divisible by groups
                s.c = (s.c / 8).max(s.groups).max(1) * s.groups / s.groups.max(1);
                if s.c % s.groups != 0 || s.c == 0 {
                    s.c = s.groups;
                }
                s.m = (s.m / 8).max(s.groups);
                if s.m % s.groups != 0 {
                    s.m = s.groups * s.m.div_ceil(s.groups);
                }
                s
            };
            let mut rng = Rng::new(name.len() as u64);
            let x = Tensor4::random_activations(
                Dims4::new(2, shape.c, shape.h, shape.w),
                &mut rng,
            );
            let w = ConvWeights::synthetic(&shape, &mut rng);
            let want = direct_dense(&shape, &x, &w);
            let g = lowered_gemm(&shape, &x, &w);
            assert!(g.allclose(&want, 1e-3, 1e-4), "{name} gemm");
            let s = lowered_spmm(&shape, &x, &w.csr_banks());
            assert!(s.allclose(&want, 1e-3, 1e-4), "{name} spmm");
            let d = sconv(&shape, &x, &w.stretched_banks());
            assert!(d.allclose(&want, 1e-3, 1e-4), "{name} sconv");
            let el = sconv_ell(&shape, &x, &w.ell_banks(8));
            assert!(el.allclose(&want, 1e-3, 1e-4), "{name} sconv_ell");
            if winograd_applicable(&shape) {
                let wg = winograd_3x3(&shape, &x, &w);
                assert!(wg.allclose(&want, 1e-2, 1e-3), "{name} winograd");
            }
        }
    }
}

#[test]
fn router_drives_scheduler_end_to_end() {
    // The router's choices must be executable by the scheduler for every
    // sparse layer of AlexNet, and feeding back observations must not
    // break subsequent runs.
    let net = network_by_name("alexnet").unwrap();
    let mut scaled = net.clone();
    for layer in &mut scaled.layers {
        if let escoin::config::LayerKind::Conv(c) = &mut layer.kind {
            *c = c.scaled_spatial(4);
        }
    }
    let sched = NetworkSchedule::build(scaled, 7, Arc::new(WorkerPool::new(2)));
    let router = Router::new(RouterConfig::default());
    for _ in 0..3 {
        let report = sched.run(1, |layer, shape| router.choose(layer, shape));
        for lt in &report.layers {
            if let Some(m) = lt.method {
                router.observe(&lt.layer, m, lt.total);
            }
        }
        assert!(report.total().as_nanos() > 0);
    }
}

#[test]
fn fig10_invariant_holds_for_all_models() {
    // The Fig 10 claim must hold for every model, not just AlexNet.
    for net in all_networks() {
        let row = fig10_cache_rates(
            &net,
            Fig10Opts {
                spatial_scale: 2,
                max_layers: 2,
            },
        );
        assert!(
            row.sconv_ro > row.csrmm_ro,
            "{}: sconv RO {:.2} <= csrmm RO {:.2}",
            net.name,
            row.sconv_ro,
            row.csrmm_ro
        );
    }
}

#[test]
fn scheduler_winograd_round_trip_on_dense_3x3() {
    let net = network_by_name("resnet").unwrap();
    // Find a dense 3x3 ungrouped layer? ResNet 3x3s are sparse; take a
    // sparse one and check Winograd still computes the right thing (it
    // ignores sparsity and uses the dense weights).
    let (name, shape) = net.sparse_conv_layers()[0].clone();
    let shape = shape.scaled_spatial(4);
    assert!(winograd_applicable(&shape), "{name}");
    let mut rng = Rng::new(3);
    let x = Tensor4::random_activations(Dims4::new(1, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let want = direct_dense(&shape, &x, &w);
    let got = winograd_3x3(&shape, &x, &w);
    assert!(got.allclose(&want, 1e-2, 1e-3));
}

#[test]
fn method_names_are_stable() {
    // The EXPERIMENTS.md tables key on these strings.
    assert_eq!(Method::LoweredGemm.name(), "lowered-gemm");
    assert_eq!(Method::LoweredSpmm.name(), "lowered-spmm");
    assert_eq!(Method::DirectSparse.name(), "direct-sparse");
    assert_eq!(Method::Winograd.name(), "winograd");
}
