//! Property-style tests on coordinator invariants (routing, batching,
//! state) and an in-process serving round trip over the shared
//! NetworkPlan executor.
//!
//! The offline toolchain has no proptest; properties are exercised with
//! seeded randomized sweeps over the deterministic `escoin::util::Rng`.

use escoin::config::ConvShape;
use escoin::conv::ConvWeights;
use escoin::coordinator::{
    Batcher, BatcherConfig, Router, RouterConfig, ServerConfig, ServerHandle,
};
use escoin::sparse::{CsrMatrix, EllMatrix, SparsityStats};
use escoin::tensor::Tensor4;
use escoin::util::Rng;
use std::sync::mpsc::channel;
use std::time::Duration;

fn random_shape(rng: &mut Rng) -> ConvShape {
    let r = [1, 3, 5][rng.below(3)];
    let pad = if r == 1 { 0 } else { rng.below((r - 1) / 2 + 2) };
    let stride = 1 + rng.below(2);
    let h = r + rng.below(8) + 2;
    let w = r + rng.below(8) + 2;
    let mut s = ConvShape::new(
        1 + rng.below(6),
        1 + rng.below(8),
        h,
        w,
        r,
        r,
        stride,
        pad,
    );
    if rng.below(2) == 1 {
        s = s.with_sparsity(0.4 + 0.5 * rng.next_f32());
    }
    s
}

#[test]
fn property_router_choice_is_always_a_candidate() {
    let mut rng = Rng::new(1);
    let router = Router::new(RouterConfig::default());
    for i in 0..300 {
        let shape = random_shape(&mut rng);
        let layer = format!("layer{}", i % 7);
        let choice = router.choose(&layer, &shape);
        assert!(
            router.candidates(&shape).contains(&choice),
            "{choice:?} not a candidate for {shape}"
        );
        // Feed a random observation to mutate state.
        let lat = Duration::from_micros(rng.below(10_000) as u64 + 1);
        router.observe(&layer, choice, lat);
    }
}

#[test]
fn property_router_converges_to_fastest_method() {
    let mut rng = Rng::new(2);
    for trial in 0..10 {
        let router = Router::new(RouterConfig {
            explore_every: 0,
            ..Default::default()
        });
        let shape = ConvShape::new(8, 8, 10, 10, 3, 3, 1, 1).with_sparsity(0.8);
        let methods = router.candidates(&shape);
        let fastest = methods[rng.below(methods.len())];
        for _ in 0..30 {
            for &m in &methods {
                let base = if m == fastest { 100 } else { 1000 + rng.below(500) as u64 };
                router.observe("l", m, Duration::from_micros(base));
            }
        }
        assert_eq!(router.choose("l", &shape), fastest, "trial {trial}");
    }
}

#[test]
fn property_batcher_never_exceeds_capacity_and_preserves_order() {
    let mut rng = Rng::new(3);
    for _ in 0..20 {
        let n = 1 + rng.below(50);
        let cap = 1 + rng.below(8);
        let (tx, rx) = channel();
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut b = Batcher::new(
            rx,
            BatcherConfig {
                batch_size: cap,
                max_wait: Duration::from_millis(1),
            },
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.items.len() <= cap);
            assert!(!batch.items.is_empty());
            seen.extend(batch.items);
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }
}

#[test]
fn property_csr_ell_dense_roundtrip() {
    let mut rng = Rng::new(4);
    for _ in 0..50 {
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(40);
        let mut dense = rng.normal_vec(rows * cols);
        // random sparsification
        for v in dense.iter_mut() {
            if rng.next_f32() < 0.7 {
                *v = 0.0;
            }
        }
        let csr = CsrMatrix::from_dense(rows, cols, &dense);
        csr.validate().unwrap();
        assert_eq!(csr.to_dense(), dense);
        let ell = EllMatrix::from_csr(&csr, 1 + rng.below(8));
        assert_eq!(ell.to_dense(), dense);
        let stats = SparsityStats::of(&csr);
        assert_eq!(stats.nnz, csr.nnz());
        assert!(stats.sparsity >= 0.0 && stats.sparsity <= 1.0);
    }
}

#[test]
fn property_stretched_offsets_always_in_reach() {
    let mut rng = Rng::new(5);
    for i in 0..40 {
        let shape = random_shape(&mut rng);
        let mut wrng = Rng::new(100 + i);
        let w = ConvWeights::synthetic(&shape, &mut wrng);
        for bank in w.stretched_banks() {
            bank.validate_reach(&shape).unwrap();
        }
    }
}

#[test]
fn property_conv_methods_agree_on_random_shapes() {
    // The three native methods are interchangeable on any valid layer.
    use escoin::conv::{direct_dense, lowered_gemm, lowered_spmm, sconv};
    use escoin::tensor::Dims4;
    let mut rng = Rng::new(6);
    for i in 0..15 {
        let shape = random_shape(&mut rng);
        let mut wrng = Rng::new(200 + i);
        let x = Tensor4::random_activations(
            Dims4::new(1 + (i as usize % 2), shape.c, shape.h, shape.w),
            &mut wrng,
        );
        let w = ConvWeights::synthetic(&shape, &mut wrng);
        let want = direct_dense(&shape, &x, &w);
        let g = lowered_gemm(&shape, &x, &w);
        let s = lowered_spmm(&shape, &x, &w.csr_banks());
        let d = sconv(&shape, &x, &w.stretched_banks());
        assert!(g.allclose(&want, 1e-3, 1e-4), "gemm {shape}");
        assert!(s.allclose(&want, 1e-3, 1e-4), "spmm {shape}");
        assert!(d.allclose(&want, 1e-3, 1e-4), "sconv {shape}");
    }
}

fn server_cfg(weight_seed: u64) -> ServerConfig {
    ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(2),
        },
        weight_seed,
        threads: 2,
        router: RouterConfig::default(),
        ..Default::default()
    }
}

#[test]
fn server_round_trip_all_requests_answered() {
    let server = ServerHandle::start(server_cfg(7)).expect("server start");
    let elems = server.image_elems();
    let classes = server.num_classes();
    let mut rng = Rng::new(9);
    let mut pending = Vec::new();
    for _ in 0..17 {
        let img = rng.activation_vec(elems);
        pending.push(server.submit(img).unwrap());
    }
    for rx in pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response")
            .expect("response ok");
        assert_eq!(resp.logits.len(), classes);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.snapshot.responses, 17);
    assert_eq!(stats.snapshot.errors, 0);
    assert!(stats.snapshot.batches >= 5); // 17 images / batch 4
    assert!(stats.snapshot.throughput_rps > 0.0);
}

#[test]
fn server_identical_images_get_identical_logits_across_batches() {
    let server = ServerHandle::start(server_cfg(7)).unwrap();
    let mut rng = Rng::new(10);
    let img = rng.activation_vec(server.image_elems());
    let a = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    let b = server.submit(img).unwrap().recv().unwrap().unwrap();
    // Batch padding / workspace reuse must not leak into results: same
    // image, same logits.
    for (x, y) in a.logits.iter().zip(&b.logits) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
    server.shutdown().unwrap();
}

#[test]
fn server_rejects_wrong_image_size() {
    let server = ServerHandle::start(server_cfg(1)).unwrap();
    assert!(server.submit(vec![0.0; 7]).is_err());
    server.shutdown().unwrap();
}

#[test]
fn server_startup_fails_cleanly_on_unknown_network() {
    let err = ServerHandle::start(ServerConfig {
        network: "nonexistent_model".into(),
        ..Default::default()
    });
    assert!(err.is_err());
}

#[test]
fn server_logits_depend_on_the_submitted_image() {
    // The serving path must actually run the submitted pixels — a zero
    // image and a random image must not produce identical logits.
    let server = ServerHandle::start(server_cfg(21)).unwrap();
    let zero = vec![0.0; server.image_elems()];
    let mut rng = Rng::new(22);
    let img = rng.activation_vec(server.image_elems());
    let a = server.submit(zero).unwrap().recv().unwrap().unwrap();
    let b = server.submit(img).unwrap().recv().unwrap().unwrap();
    assert_ne!(a.logits, b.logits);
    server.shutdown().unwrap();
}

#[test]
fn server_replans_when_the_router_changes_its_mind() {
    // Aggressive replanning on a tiny cadence: the server must keep
    // answering correctly across plan recompiles (weights are
    // re-derived from the seed, so logits for one image stay stable).
    let mut cfg = server_cfg(13);
    cfg.replan_every = 2;
    cfg.router = RouterConfig {
        explore_every: 3, // force method churn
        ..Default::default()
    };
    let server = ServerHandle::start(cfg).unwrap();
    let mut rng = Rng::new(14);
    let img = rng.activation_vec(server.image_elems());
    let first = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    for _ in 0..20 {
        let resp = server.submit(img.clone()).unwrap().recv().unwrap().unwrap();
        // Methods may differ across replans; results must agree to fp
        // accumulation tolerance.
        for (x, y) in resp.logits.iter().zip(&first.logits) {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs().max(x.abs()),
                "{x} vs {y} after replan"
            );
        }
    }
    server.shutdown().unwrap();
}

#[test]
fn property_admission_accounting_under_bursty_arrivals() {
    // Admission-control accounting: admitted + rejected == submitted
    // attempts, rejections are surfaced as errors (never silently
    // dropped), and a rejection never corrupts an in-flight pipeline
    // slot — every admitted request still gets a full, finite logit
    // vector.
    let mut cfg = server_cfg(31);
    cfg.max_queue_depth = 3;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let server = ServerHandle::start(cfg).unwrap();
    let elems = server.image_elems();
    let classes = server.num_classes();
    let mut rng = Rng::new(32);
    let mut pending = Vec::new();
    let mut rejected = 0u64;
    let attempts = 120u64;
    for burst in 0..attempts {
        let img = rng.activation_vec(elems);
        match server.submit(img) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                rejected += 1;
                assert!(
                    e.to_string().contains("rejected"),
                    "rejection must be explicit: {e}"
                );
            }
        }
        // Periodically drain so both the admit and the reject path are
        // exercised across several bursts.
        if burst % 17 == 16 {
            for rx in pending.drain(..) {
                let resp = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("response")
                    .expect("response ok");
                assert_eq!(resp.logits.len(), classes);
                assert!(resp.logits.iter().all(|v| v.is_finite()));
            }
        }
    }
    for rx in pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response")
            .expect("response ok");
        assert_eq!(resp.logits.len(), classes);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.shutdown().unwrap();
    let s = &stats.snapshot;
    // The executor answers in milliseconds while the bursts submit in
    // microseconds, so a depth-3 bound must have rejected something.
    assert!(rejected > 0, "burst never hit the admission bound");
    assert_eq!(s.rejected, rejected);
    assert_eq!(s.requests + s.rejected, attempts);
    assert_eq!(s.responses, s.requests, "every admitted request answered");
    assert_eq!(s.errors, 0);
    assert_eq!(s.queue_depth, 0, "admission gauge must drain to zero");
}

#[test]
fn property_ell_fixed_k_respects_manifest_contract() {
    use escoin::sparse::EllMatrix;
    let mut rng = Rng::new(11);
    for _ in 0..30 {
        let rows = 1 + rng.below(16);
        let cols = 8 + rng.below(64);
        let sparsity = 0.5 + 0.4 * rng.next_f32();
        let mut dense = rng.normal_vec(rows * cols);
        escoin::sparse::prune_magnitude_per_row(&mut dense, cols, sparsity);
        let csr = CsrMatrix::from_dense(rows, cols, &dense);
        let k = csr.max_row_nnz().max(1);
        let ell = EllMatrix::from_csr_fixed_k(&csr, k + rng.below(8));
        assert_eq!(ell.to_dense(), dense);
    }
}

#[test]
fn property_batcher_formation_time_respects_deadline() {
    // A starved batcher must emit within ~max_wait of the first arrival.
    let (tx, rx) = channel();
    let mut b = Batcher::new(
        rx,
        BatcherConfig {
            batch_size: 64,
            max_wait: Duration::from_millis(10),
        },
    );
    tx.send(1u32).unwrap();
    let batch = b.next_batch().unwrap();
    assert_eq!(batch.items.len(), 1);
    assert!(
        batch.formation_time < Duration::from_millis(100),
        "{:?}",
        batch.formation_time
    );
}
