//! Property tests for the cache-model substrate the autotuner ranks
//! [`TilePolicy`] candidates on (`simulator::{cache, coalesce,
//! memory}`): true-LRU replacement against a reference recency model,
//! hit-count monotonicity in associativity/capacity, warp-coalescing
//! invariants, and the flush / reset / kernel-boundary semantics the
//! per-candidate sweep isolation depends on.
//!
//! [`TilePolicy`]: escoin::conv::TilePolicy

use escoin::simulator::{
    coalesce_warp, AccessKind, Cache, CacheConfig, CacheStats, MemoryHierarchy,
};
use escoin::util::Rng;

/// A deterministic address trace with enough locality to produce both
/// hits and misses at every geometry under test: a random walk over a
/// working set a few times larger than the smallest cache, with
/// occasional far jumps.
fn trace(len: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut addr: u64 = 0;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        match rng.below(8) {
            0 => addr = (rng.below(1 << 16)) as u64, // far jump
            1..=4 => addr = addr.wrapping_add(rng.below(256) as u64), // near walk
            _ => {} // re-touch (temporal locality)
        }
        out.push(addr % (1 << 16));
    }
    out
}

/// Reference model: one recency-ordered line list per set, MRU first.
/// `Cache::access` must agree with it on every single access.
struct ModelLru {
    cfg: CacheConfig,
    sets: Vec<Vec<u64>>,
}

impl ModelLru {
    fn new(cfg: CacheConfig) -> Self {
        Self {
            sets: vec![Vec::new(); cfg.sets()],
            cfg,
        }
    }

    fn access(&mut self, addr: u64) -> bool {
        let line = addr / self.cfg.line_bytes as u64;
        let set = &mut self.sets[(line % self.sets.len() as u64) as usize];
        if let Some(pos) = set.iter().position(|&t| t == line) {
            let t = set.remove(pos);
            set.insert(0, t);
            true
        } else {
            set.insert(0, line);
            set.truncate(self.cfg.ways);
            false
        }
    }
}

/// The cache is *exactly* true-LRU: every access agrees hit-for-hit
/// with an independent recency-list model, across several geometries
/// (including the degenerate direct-mapped and single-set cases).
#[test]
fn property_cache_matches_a_reference_lru_model_access_for_access() {
    let geometries = [
        (512usize, 64usize, 2usize), // tiny, 4 sets
        (256, 64, 4),                // single set, pure LRU stack
        (1024, 32, 1),               // direct-mapped
        (4096, 128, 8),              // L2-ish shape
    ];
    for (size_bytes, line_bytes, ways) in geometries {
        let cfg = CacheConfig {
            size_bytes,
            line_bytes,
            ways,
        };
        let mut cache = Cache::new(cfg);
        let mut model = ModelLru::new(cfg);
        let mut hits = 0u64;
        for (i, &addr) in trace(20_000, 7).iter().enumerate() {
            let want = model.access(addr);
            let got = cache.access(addr);
            assert_eq!(
                got, want,
                "access {i} (addr {addr:#x}) diverged from the LRU model at \
                 {size_bytes}B/{line_bytes}B/{ways}w"
            );
            hits += want as u64;
        }
        assert_eq!(cache.stats().hits, hits, "hit counter drifted");
        assert_eq!(cache.stats().accesses(), 20_000);
        // The trace is built to exercise both outcomes everywhere.
        assert!(cache.stats().hits > 0 && cache.stats().misses > 0);
    }
}

/// LRU inclusion property: at a fixed set count and line size, a cache
/// with more ways holds a superset of every narrower cache's contents
/// after any access sequence — so hits are monotone non-decreasing in
/// associativity. Since capacity here is `sets * line * ways`, the same
/// walk is also capacity monotonicity at fixed set count (the form in
/// which the property actually holds; growing the set count instead
/// re-hashes lines and is *not* monotone in general).
#[test]
fn property_hits_are_monotone_in_ways_at_fixed_sets() {
    const SETS: usize = 16;
    const LINE: usize = 32;
    for seed in [1u64, 2, 3] {
        let t = trace(30_000, seed);
        let mut prev_hits = None;
        for ways in [1usize, 2, 4, 8, 16] {
            let mut cache = Cache::new(CacheConfig {
                size_bytes: SETS * LINE * ways,
                line_bytes: LINE,
                ways,
            });
            assert_eq!(cache.config().sets(), SETS);
            for &a in &t {
                cache.access(a);
            }
            let hits = cache.stats().hits;
            if let Some(prev) = prev_hits {
                assert!(
                    hits >= prev,
                    "seed {seed}: {ways} ways hit {hits} < narrower cache's {prev}"
                );
            }
            prev_hits = Some(hits);
        }
    }
}

/// Wider caches can only convert misses to hits, never change the
/// access count — so `hit_rate` is monotone too and bounded by [0, 1].
#[test]
fn property_hit_rate_is_monotone_and_bounded() {
    let t = trace(10_000, 11);
    let mut prev = -1.0f64;
    for ways in [1usize, 2, 4, 8] {
        let mut cache = Cache::new(CacheConfig {
            size_bytes: 8 * 64 * ways,
            line_bytes: 64,
            ways,
        });
        for &a in &t {
            cache.access(a);
        }
        let r = cache.stats().hit_rate();
        assert!((0.0..=1.0).contains(&r));
        assert!(r >= prev, "{ways} ways regressed the hit rate");
        prev = r;
    }
    // Idle caches report 0.0, not NaN.
    assert_eq!(CacheStats::default().hit_rate(), 0.0);
}

/// `coalesce_warp` output is sorted, duplicate-free, line-aligned,
/// covers exactly the input's distinct lines, and is invariant under
/// lane permutation — the §3.2 transaction rule as an algebra.
#[test]
fn property_coalesce_warp_dedups_and_line_aligns() {
    let mut rng = Rng::new(23);
    for line_bytes in [32usize, 64, 128] {
        let mask = !(line_bytes as u64 - 1);
        for _ in 0..200 {
            let lanes: Vec<u64> = (0..32).map(|_| rng.below(1 << 14) as u64).collect();
            let lines = coalesce_warp(&lanes, line_bytes);
            // Strictly increasing (sorted + deduped in one check).
            assert!(lines.windows(2).all(|w| w[0] < w[1]));
            // Line-aligned, and never more transactions than lanes.
            assert!(lines.iter().all(|l| l & !mask == 0));
            assert!(lines.len() <= lanes.len());
            // Exactly the set of distinct lines the lanes touch.
            for a in &lanes {
                assert!(lines.binary_search(&(a & mask)).is_ok());
            }
            let mut distinct: Vec<u64> = lanes.iter().map(|a| a & mask).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert_eq!(lines, distinct);
            // Order of lanes within the warp is irrelevant.
            let mut shuffled = lanes.clone();
            rng.shuffle(&mut shuffled);
            assert_eq!(coalesce_warp(&shuffled, line_bytes), lines);
        }
    }
}

/// `flush` and `reset_stats` are exact complements: one clears contents
/// and keeps counters, the other clears counters and keeps contents.
#[test]
fn flush_and_reset_stats_are_complementary() {
    let cfg = CacheConfig {
        size_bytes: 1024,
        line_bytes: 64,
        ways: 4,
    };
    let mut cache = Cache::new(cfg);
    for addr in (0..1024u64).step_by(64) {
        cache.access(addr);
    }
    let filled = cache.stats();
    assert_eq!(filled.misses, 16);

    // reset_stats: counters go to zero, the working set stays resident.
    cache.reset_stats();
    assert_eq!(cache.stats(), CacheStats::default());
    for addr in (0..1024u64).step_by(64) {
        assert!(cache.access(addr), "reset_stats must not evict {addr:#x}");
    }
    assert_eq!(cache.stats(), CacheStats { hits: 16, misses: 0 });

    // flush: contents go away, the counters keep accumulating.
    cache.flush();
    let before = cache.stats();
    for addr in (0..1024u64).step_by(64) {
        assert!(!cache.access(addr), "flush must evict {addr:#x}");
    }
    assert_eq!(cache.stats().hits, before.hits);
    assert_eq!(cache.stats().misses, before.misses + 16);
}

/// `kernel_boundary` models a new launch: per-SM read-only caches flush
/// (their stats survive), the shared L2 keeps both lines and stats —
/// which is exactly why the autotuner scores each candidate on a fresh
/// hierarchy rather than a boundary: L2 state would otherwise leak
/// between candidates.
#[test]
fn kernel_boundary_flushes_ro_contents_only() {
    let mut mem = MemoryHierarchy::p100();
    let warp: Vec<u64> = (0..32).map(|i| i * 4).collect();
    for sm in 0..4 {
        mem.warp_access_on(sm, &warp, AccessKind::ReadOnly);
    }
    let before = mem.report();
    assert!(before.ro.misses > 0);

    mem.kernel_boundary();
    let at_boundary = mem.report();
    // Stats are untouched by the boundary itself.
    assert_eq!(at_boundary.ro, before.ro);
    assert_eq!(at_boundary.l2, before.l2);
    assert_eq!(at_boundary.dram_bytes, before.dram_bytes);

    // Re-reading after the boundary: RO misses again on every SM, but
    // L2 serves the refills without new DRAM traffic.
    for sm in 0..4 {
        mem.warp_access_on(sm, &warp, AccessKind::ReadOnly);
    }
    let after = mem.report();
    assert_eq!(after.ro.hits, before.ro.hits, "RO lines must be gone");
    assert!(after.ro.misses > before.ro.misses);
    assert!(after.l2.hits > before.l2.hits, "L2 lines must survive");
    assert_eq!(after.dram_bytes, before.dram_bytes);
}

/// Access-kind routing: read-only traffic fills the per-SM RO caches,
/// global reads/writes bypass them, and every L2 miss costs exactly one
/// line of DRAM traffic.
#[test]
fn access_kinds_route_to_the_documented_levels() {
    let mut mem = MemoryHierarchy::p100();
    let l2_line = 128u64;

    mem.access(0, AccessKind::GlobalRead);
    let r = mem.report();
    assert_eq!(r.ro.accesses(), 0);
    assert_eq!((r.l2.accesses(), r.dram_bytes), (1, l2_line));

    mem.access(4096, AccessKind::GlobalWrite);
    let r = mem.report();
    assert_eq!(r.ro.accesses(), 0);
    assert_eq!((r.l2.accesses(), r.dram_bytes), (2, 2 * l2_line));

    mem.access(8192, AccessKind::ReadOnly);
    let r = mem.report();
    assert_eq!(r.ro.accesses(), 1);
    assert_eq!((r.l2.accesses(), r.dram_bytes), (3, 3 * l2_line));

    // A repeat read-only access is satisfied by the RO cache and never
    // reaches L2 or DRAM.
    mem.access(8192, AccessKind::ReadOnly);
    let r = mem.report();
    assert_eq!(r.ro.hits, 1);
    assert_eq!((r.l2.accesses(), r.dram_bytes), (3, 3 * l2_line));
}
