//! Worker-pool runtime properties: tile accounting, telemetry, the
//! load-balance claim behind nnz-weighted tiling — one dense output
//! channel among 95%-sparse channels must not turn into a straggler the
//! way it does under the seed's equal-plane splitting — and the
//! critical-path priority queue: higher-priority runnable jobs dequeue
//! first, priorities never override dependency order, and prioritized
//! scheduling never changes bytes.

use escoin::config::{miniception, ConvShape};
use escoin::conv::{
    direct_dense, ConvWeights, DirectSparsePlan, LayerPlan, Method, NetworkPlan, TilePolicy,
    WorkspaceArena,
};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{JobOrigin, Rng, WorkerPool};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Mutex;

#[test]
fn pool_executes_all_tiles_and_accounts_them() {
    for threads in [1, 2, 4] {
        let pool = WorkerPool::new(threads);
        let sum = AtomicU64::new(0);
        for job in 0..5u64 {
            pool.run(13, &|t, w| {
                assert!(w < pool.workers());
                sum.fetch_add(job + t as u64, Ordering::Relaxed);
            });
        }
        let expect: u64 = (0..5u64).map(|j| 13 * j + (0..13).sum::<u64>()).sum();
        assert_eq!(sum.load(Ordering::Relaxed), expect, "t{threads}");
        let stats = pool.stats();
        assert_eq!(stats.jobs, 5);
        assert_eq!(stats.total_tiles(), 65);
        assert_eq!(
            stats.total_tiles(),
            stats.inline_tiles + stats.tiles.iter().sum::<u64>(),
            "inline + per-worker tiles must sum to the total"
        );
    }
}

/// Weights with one fully dense output channel among 95%-sparse ones —
/// the skew that motivated nnz-weighted tiling.
fn skewed_weights(shape: &ConvShape, dense_channel: usize) -> ConvWeights {
    let per_ch = shape.c_per_group() * shape.r * shape.s;
    let mut dense = vec![0.0f32; shape.weights()];
    for m in 0..shape.m {
        for i in 0..per_ch {
            // Sparse channels keep 1 in 20 weights (95% sparse).
            if m == dense_channel || i % 20 == 0 {
                dense[m * per_ch + i] = 0.25 + ((m * 31 + i * 7) % 13) as f32 * 0.1;
            }
        }
    }
    ConvWeights::from_dense(shape, dense)
}

/// Simulate scheduling `weights`-sized tiles onto `workers` lanes the
/// way the dynamic queue does (each next tile goes to the least-loaded
/// lane) and return max-lane-load / mean-lane-load.
fn schedule_imbalance(weights: &[usize], workers: usize) -> f64 {
    let mut load = vec![0usize; workers];
    for &w in weights {
        let min = load
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| **l)
            .map(|(i, _)| i)
            .unwrap();
        load[min] += w;
    }
    let total: usize = load.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / workers as f64;
    *load.iter().max().unwrap() as f64 / mean
}

/// The ISSUE's stress property: with one dense channel among 95%-sparse
/// channels, equal-plane splitting leaves one worker with a multiple of
/// the mean FLOPs, while the plan's nnz-weighted tiles schedule to
/// near-equal per-worker work. Asserted on tile nnz weights — not
/// wall-clock.
#[test]
fn nnz_weighted_tiling_beats_equal_plane_splitting_on_skewed_sparsity() {
    // 64 channels over 16 input channels of 3x3 taps = 144 weights per
    // channel; channel 11 fully dense, the rest ~95% sparse.
    let shape = ConvShape::new(16, 64, 10, 10, 3, 3, 1, 1);
    let w = skewed_weights(&shape, 11);
    let plan = DirectSparsePlan::build(&shape, &w);
    let tiles = plan.tiles();
    let tile_nnz = plan.tile_nnz();
    let workers = 4;

    // Enough tiles for the dynamic queue to rebalance around.
    assert!(tiles.len() > workers, "only {} tiles", tiles.len());

    // Equal-plane splitting: contiguous chunks of M/workers channels.
    let per_ch: Vec<usize> = {
        let banks = plan.banks();
        let mg = shape.m_per_group();
        (0..shape.m)
            .map(|m| banks[m / mg].csr.row_nnz(m % mg))
            .collect()
    };
    let chunk = shape.m.div_ceil(workers);
    let equal_plane: Vec<usize> = per_ch.chunks(chunk).map(|c| c.iter().sum()).collect();
    let equal_imbalance = schedule_imbalance(&equal_plane, workers);

    let weighted_imbalance = schedule_imbalance(tile_nnz, workers);

    assert!(
        equal_imbalance > 1.5,
        "skew did not unbalance equal-plane splitting ({equal_imbalance:.2})"
    );
    assert!(
        weighted_imbalance < 1.25,
        "nnz-weighted tiles still unbalanced ({weighted_imbalance:.2})"
    );
    assert!(
        weighted_imbalance < equal_imbalance,
        "weighted {weighted_imbalance:.2} vs equal-plane {equal_imbalance:.2}"
    );
}

/// The ISSUE's feedback-loop property: start a skewed-sparsity layer on
/// deliberately **coarse** tiles, measure the pool's real per-job
/// imbalance telemetry, feed it through `TilePolicy::adjusted` (the
/// exact signal path the serving executor's replan uses), and verify
/// the retiled plan schedules measurably more evenly — on the real pool
/// *and* under the deterministic least-loaded schedule model.
#[test]
fn adaptive_retiling_from_telemetry_reduces_measured_imbalance() {
    // Skewed layer sized so every tile carries enough FLOPs for all
    // workers to wake and participate (span ~ 64*66 floats).
    let shape = ConvShape::new(16, 64, 64, 64, 3, 3, 1, 1);
    let w = skewed_weights(&shape, 11);
    let workers = 5;
    let batch = 2;
    let pool = WorkerPool::new(workers);
    let mut rng = Rng::new(17);
    let x = Tensor4::random_activations(Dims4::new(batch, 16, 64, 64), &mut rng);

    // Coarse start: ~3 nnz-balanced channel tiles -> 6 pool tiles per
    // job. The telemetry counts the submitting lane as eligible only
    // when it claimed tiles, so the per-job floor must hold for BOTH
    // lane counts: 6 tiles over 5 lanes floors at ceil(6/5)/(6/5) =
    // 1.67, over the 4 spawned lanes at ceil(6/4)/(6/4) = 1.33 — either
    // way above the 1.25 refine threshold, so the premise cannot race
    // away.
    let mut policy = TilePolicy {
        target_tiles: 3,
        ..TilePolicy::default()
    };
    let coarse_plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
    let reference = coarse_plan.run(&x, &pool);
    // Deterministic 4-lane schedule model (independent of pool races):
    // the coarse split leaves the schedule lopsided.
    const SIM_LANES: usize = 4;
    {
        let sparse = DirectSparsePlan::build_with_policy(&shape, &w, policy);
        assert_eq!(
            sparse.tiles().len(),
            3,
            "premise: coarse policy must cut ~3 channel tiles"
        );
        assert!(
            schedule_imbalance(sparse.tile_nnz(), SIM_LANES) > 1.25,
            "premise: coarse tiles must schedule unevenly"
        );
    }

    let runs_per_round = 8;
    let mut measured: Vec<f64> = Vec::new();
    let mut anchor = pool.stats();
    for _round in 0..8 {
        let plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
        for _ in 0..runs_per_round {
            let out = plan.run(&x, &pool);
            // Tile geometry must never change the bytes.
            assert_eq!(out.data(), reference.data(), "retile changed results");
        }
        let now = pool.stats();
        let (imbalance, steal_rate) = now
            .interval_tiling_signal(&anchor)
            .expect("distributed jobs ran");
        anchor = now;
        measured.push(imbalance);
        match policy.adjusted(imbalance, steal_rate) {
            Some(next) => policy = next,
            None => break,
        }
    }

    let first = measured[0];
    let last = *measured.last().unwrap();
    assert!(
        first > TilePolicy::REFINE_IMBALANCE,
        "coarse tiling must measure imbalanced (got {first:.2})"
    );
    assert!(
        policy.target_tiles > 3,
        "telemetry must have refined the tile target (still {})",
        policy.target_tiles
    );
    assert!(
        last < first,
        "refined tiling must measure more balanced ({last:.2} vs {first:.2})"
    );

    // The refined granularity also wins under the deterministic
    // least-loaded schedule model (no scheduling races involved) —
    // asserted at the default 48-tile target the loop refines toward,
    // so the bound does not depend on which round the loop stopped at.
    let fine = DirectSparsePlan::build_with_policy(&shape, &w, TilePolicy::default());
    let coarse = DirectSparsePlan::build_with_policy(
        &shape,
        &w,
        TilePolicy {
            target_tiles: 3,
            ..TilePolicy::default()
        },
    );
    let fine_sim = schedule_imbalance(fine.tile_nnz(), SIM_LANES);
    let coarse_sim = schedule_imbalance(coarse.tile_nnz(), SIM_LANES);
    assert!(
        fine_sim < 1.25,
        "refined tiles still schedule unevenly ({fine_sim:.2})"
    );
    assert!(fine_sim < coarse_sim, "{fine_sim:.2} vs {coarse_sim:.2}");
}

/// Hold a pool with exactly one spawned worker (`new(2)`) inside a gate
/// job, queue `jobs` behind it, release the gate, and return the labels
/// in execution order. Because one worker drains the whole queue
/// sequentially — and the submitting thread never helps until every
/// label has been received — the received order *is* the dequeue order.
fn dequeue_order(
    jobs: &[(&'static str, u64, &[usize])], // (label, priority, dep indices)
) -> Vec<&'static str> {
    let pool = WorkerPool::new(2);
    let (gate_tx, gate_rx) = channel::<()>();
    let gate_rx = Mutex::new(gate_rx);
    let (entered_tx, entered_rx) = channel::<()>();
    let gate = pool.submit_owned(
        1,
        Box::new(move |_t, _w| {
            entered_tx.send(()).unwrap();
            gate_rx.lock().unwrap().recv().unwrap();
        }),
        JobOrigin::Dag,
        &[],
    );
    // The worker is provably inside the gate tile: everything submitted
    // from here queues behind it.
    entered_rx.recv().unwrap();

    let (label_tx, label_rx) = channel::<&'static str>();
    let mut handles = Vec::new();
    for (label, priority, deps) in jobs {
        let tx = label_tx.clone();
        let label = *label;
        let handle = {
            let dep_handles: Vec<_> = deps.iter().map(|&i| &handles[i]).collect();
            pool.submit_owned_prioritized(
                1,
                Box::new(move |_t, _w| tx.send(label).unwrap()),
                JobOrigin::Dag,
                *priority,
                &dep_handles,
            )
        };
        handles.push(handle);
    }
    gate_tx.send(()).unwrap();
    let order: Vec<&'static str> = (0..jobs.len()).map(|_| label_rx.recv().unwrap()).collect();
    // All tiles have executed; joining the handles is now race-free.
    for h in handles {
        h.wait();
    }
    gate.wait();
    order
}

/// The ISSUE's priority property: when several queued jobs are
/// runnable, the highest priority dequeues first, and equal priorities
/// keep their FIFO submission order.
#[test]
fn higher_priority_jobs_dequeue_before_lighter_siblings() {
    let order = dequeue_order(&[
        ("light-a", 0, &[]),
        ("critical", 5, &[]),
        ("light-b", 0, &[]),
    ]);
    assert_eq!(order, vec!["critical", "light-a", "light-b"]);
}

/// Priorities schedule among *runnable* jobs only: a high-priority job
/// that depends on a low-priority prerequisite must wait for it, while
/// an unrelated mid-priority job overtakes both.
#[test]
fn priorities_never_violate_dependency_order() {
    let order = dequeue_order(&[
        ("prereq", 0, &[]),
        ("mid", 50, &[]),
        ("wants-prereq", 100, &[0]),
    ]);
    assert_eq!(order, vec!["mid", "prereq", "wants-prereq"]);
    // Sanity: flipping the dependency off restores pure priority order.
    let order = dequeue_order(&[("prereq", 0, &[]), ("mid", 50, &[]), ("free", 100, &[])]);
    assert_eq!(order, vec!["free", "mid", "prereq"]);
}

/// Critical-path-weighted DAG serving is pure scheduling: the async
/// walk (whose jobs now carry critical-path priorities) must stay
/// byte-identical to the sequential walk at every pool size.
#[test]
fn prioritized_dag_walk_is_byte_identical_across_pool_sizes() {
    let net = miniception();
    let plan = NetworkPlan::build(&net, 2, 42, |_, _| Method::DirectSparse);
    let mut rng = Rng::new(91);
    let img = rng.activation_vec(plan.input_dims().len());
    let seq_pool = WorkerPool::new(1);
    let mut seq_arena = WorkspaceArena::for_plan(&plan, &seq_pool);
    let want = plan.run_with_input(&img, &seq_pool, &mut seq_arena).to_vec();
    assert!(want.iter().any(|&v| v != 0.0), "vacuous all-zero oracle");
    for threads in [1, 4, 8] {
        let pool = WorkerPool::new(threads);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let got = plan.run_async(Some(&img), &pool, &mut arena).to_vec();
        assert_eq!(got, want, "t{threads}: DAG walk diverged");
    }
}

/// The skewed layer must also *compute* correctly through the pool at
/// several worker counts, byte-identical to the single-thread run.
#[test]
fn skewed_layer_is_correct_and_deterministic_through_the_pool() {
    let shape = ConvShape::new(16, 64, 10, 10, 3, 3, 1, 1);
    let w = skewed_weights(&shape, 11);
    let mut rng = Rng::new(3);
    let x = Tensor4::random_activations(Dims4::new(2, 16, 10, 10), &mut rng);
    let want = direct_dense(&shape, &x, &w);
    let plan = LayerPlan::build(&shape, &w, Method::DirectSparse);
    let reference = plan.run(&x, &WorkerPool::new(1));
    assert!(reference.allclose(&want, 1e-3, 1e-4));
    for threads in [2, 4, 16] {
        let pool = WorkerPool::new(threads);
        let got = plan.run(&x, &pool);
        assert_eq!(got.data(), reference.data(), "t{threads}");
        // Multi-worker jobs ran, and every tile is accounted for.
        let stats = pool.stats();
        assert!(stats.total_tiles() > 0);
    }
}
