//! Trace-fidelity suite: the simulator's microkernel address generators
//! ([`trace_sconv_input_addresses`]) must read **exactly** the
//! padded-input addresses the real direct-sparse kernels read — else
//! the autotuner would rank policies on a phantom access pattern. The
//! real reads come from the test-only `conv::recording` hook, whose
//! record sites are compiled only under `debug_assertions`; every test
//! here skips itself in release builds (`recording::enabled()`).
//!
//! The recorder is process-global, so every test in this file holds one
//! lock while recording — tests stay correct under the default parallel
//! test runner.

use escoin::config::ConvShape;
use escoin::conv::{
    recording, shapes_under_test, ConvWeights, LayerPlan, Method, SparseLayout, TilePolicy,
    SIMD_LANES,
};
use escoin::simulator::trace_sconv_input_addresses;
use escoin::sparse::BalancedCsr;
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{Rng, WorkerPool};
use std::collections::BTreeSet;
use std::sync::Mutex;

/// Serializes recorder use across tests (the hook is process-global).
static RECORDER: Mutex<()> = Mutex::new(());

fn case(shape: &ConvShape, batch: usize, seed: u64) -> (Tensor4, ConvWeights) {
    let mut rng = Rng::new(seed);
    let x = Tensor4::random_activations(Dims4::new(batch, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(shape, &mut rng);
    (x, w)
}

/// Run the compiled DirectSparse plan once and return the set of
/// absolute padded-input indices the kernels recorded reading.
fn recorded_input_set(
    shape: &ConvShape,
    x: &Tensor4,
    w: &ConvWeights,
    policy: TilePolicy,
    pool: &WorkerPool,
) -> BTreeSet<usize> {
    let plan = LayerPlan::build_with_policy(shape, w, Method::DirectSparse, policy);
    recording::start();
    let _ = plan.run(x, pool);
    let mut set = BTreeSet::new();
    for (start, len, step) in recording::take() {
        for k in 0..len {
            set.insert(start + k * step);
        }
    }
    set
}

/// The simulator's claim: the address set its walk of `(shape, policy)`
/// produces, with the same operands the plan would bake.
fn traced_input_set(shape: &ConvShape, w: &ConvWeights, policy: TilePolicy) -> BTreeSet<usize> {
    let banks = w.stretched_banks();
    let balanced: Option<Vec<BalancedCsr>> = (policy.layout == SparseLayout::Balanced).then(|| {
        banks
            .iter()
            .map(|b| BalancedCsr::from_csr(&b.csr, policy.mr.max(1)))
            .collect()
    });
    trace_sconv_input_addresses(shape, &banks, balanced.as_deref(), &policy)
        .into_iter()
        .collect()
}

/// The policy spread the fidelity grid runs: the scalar register-blocked
/// kernel (default-ish and deliberately odd geometry), the unblocked
/// per-channel oracle shape, the vectorized kernel, and the
/// bank-balanced vectorized kernel. `lanes` is set explicitly so the
/// same variants are pinned on both the default and `--features simd`
/// CI legs.
fn fidelity_policies() -> Vec<TilePolicy> {
    let scalar = TilePolicy {
        lanes: 1,
        layout: SparseLayout::Csr,
        ..TilePolicy::default()
    };
    vec![
        scalar,
        TilePolicy {
            target_tiles: 5,
            mr: 3,
            block_floats: 33,
            ..scalar
        },
        TilePolicy {
            mr: 1,
            block_floats: usize::MAX,
            ..scalar
        },
        TilePolicy {
            lanes: SIMD_LANES,
            block_floats: 256,
            ..scalar
        },
        TilePolicy {
            lanes: SIMD_LANES,
            layout: SparseLayout::Balanced,
            ..scalar
        },
    ]
}

/// The core fidelity contract, over the canonical shape grid (stride-1,
/// strided, grouped, depthwise, 1x1) × the kernel-variant policy
/// spread: traced address set == recorded address set, exactly.
#[test]
fn property_traced_addresses_equal_the_kernels_recorded_reads() {
    if !recording::enabled() {
        return; // record sites compile only under debug_assertions
    }
    let _guard = RECORDER.lock().unwrap_or_else(|e| e.into_inner());
    let pool = WorkerPool::new(2);
    for (i, shape) in shapes_under_test().into_iter().enumerate() {
        let (x, w) = case(&shape, 1, 4000 + i as u64);
        for policy in fidelity_policies() {
            let got = recorded_input_set(&shape, &x, &w, policy, &pool);
            let want = traced_input_set(&shape, &w, policy);
            assert!(!want.is_empty(), "{shape}: trace produced no reads");
            assert_eq!(
                got, want,
                "{shape} with {policy:?}: kernel reads diverge from the trace"
            );
            // Sanity: every address stays inside the padded image.
            let img = shape.c * shape.padded_h() * shape.padded_w();
            assert!(*want.iter().next_back().unwrap() < img);
        }
    }
}

/// The recorded set is invariant across pool sizes: tile decomposition
/// is fixed by the policy, never by the worker count — so one traced
/// stream stands for every pool the plan may run on (the tuner scores
/// it once, pools 1/4/8 all match it).
#[test]
fn recorded_reads_are_pool_invariant() {
    if !recording::enabled() {
        return;
    }
    let _guard = RECORDER.lock().unwrap_or_else(|e| e.into_inner());
    // One stride-1 and one strided+grouped representative keep this
    // fast; the full grid above already pins every variant at pool 2.
    let shapes = [
        ConvShape::new(3, 4, 6, 6, 3, 3, 1, 1).with_sparsity(0.7),
        ConvShape::new(4, 6, 9, 9, 3, 3, 2, 1)
            .with_groups(2)
            .with_sparsity(0.5),
    ];
    for (i, shape) in shapes.into_iter().enumerate() {
        let (x, w) = case(&shape, 1, 4400 + i as u64);
        for policy in fidelity_policies() {
            let reference = recorded_input_set(&shape, &x, &w, policy, &WorkerPool::new(1));
            for workers in [4usize, 8] {
                let got = recorded_input_set(&shape, &x, &w, policy, &WorkerPool::new(workers));
                assert_eq!(got, reference, "{shape} with {policy:?} at {workers} workers");
            }
            assert_eq!(reference, traced_input_set(&shape, &w, policy));
        }
    }
}

/// Batch composition: the batch-`N` recorded set is exactly the batch-1
/// trace shifted by each image's base — the reuse pattern is per-image,
/// which is why the tuner traces batch 1 and the ranking carries to any
/// batch.
#[test]
fn batched_reads_are_the_per_image_trace_replicated() {
    if !recording::enabled() {
        return;
    }
    let _guard = RECORDER.lock().unwrap_or_else(|e| e.into_inner());
    let shape = ConvShape::new(3, 4, 6, 6, 3, 3, 1, 1).with_sparsity(0.7);
    let (x, w) = case(&shape, 3, 4800);
    let pool = WorkerPool::new(2);
    let policy = fidelity_policies()[0];

    let got = recorded_input_set(&shape, &x, &w, policy, &pool);
    let per_image = traced_input_set(&shape, &w, policy);
    let img = shape.c * shape.padded_h() * shape.padded_w();
    let want: BTreeSet<usize> = (0..3)
        .flat_map(|n| per_image.iter().map(move |a| a + n * img))
        .collect();
    assert_eq!(got, want);
}
