//! Deterministic chaos suite (`--features fault-inject` builds only):
//! the ISSUE's acceptance properties for seeded fault injection and
//! supervised serving.
//!
//! * **Blast radius** — a planned tile panic targeted at one serving
//!   batch fails exactly that request with the typed
//!   [`ServerError::Faulted`]; every other request (including ones
//!   staged *after* the fault) answers with logits byte-identical to an
//!   un-faulted run of the same seed — at pool sizes 1, 4, and 8,
//!   because the fault context rides the batch sequence number, not
//!   worker scheduling. A planned straggler perturbs timing only.
//! * **Degradation ladder** — a NaN-poisoned sconv layer trips the
//!   pre-retirement finite check and the slot's requests are retried
//!   once on the safe path (batch-1, scalar `DirectSparse`,
//!   `TilePolicy::unblocked()`), answering byte-identically to that
//!   oracle run stand-alone — with the sticky fault suppressed during
//!   the retry.
//! * **Circuit breaker** — repeated faults quarantine the charged
//!   (layer, method) pairs (visible as `method_quarantines` and an
//!   immediate replan), and healthy traffic past the decision-counted
//!   cooldown reinstates them (`method_reinstates`).
//!
//! The installed [`FaultPlan`] is process-global, so every test
//! serialises on one mutex and clears the plan before returning.

#![cfg(feature = "fault-inject")]

use escoin::config::{network_by_name, LayerKind};
use escoin::conv::{Method, PlanCache, TilePolicy, WorkspaceArena};
use escoin::coordinator::{BatcherConfig, RouterConfig, ServerConfig, ServerError, ServerHandle};
use escoin::util::fault::{self, FaultKind, FaultPlan, FaultSpec, SITE_POOL_TILE, SITE_SCONV_TILE};
use escoin::util::{Rng, WorkerPool};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// One chaos scenario at a time: the fault plan is process-global.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn chaos_guard() -> MutexGuard<'static, ()> {
    // A panicked scenario must not wedge the rest of the suite.
    CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Single-tenant minicnn at batch 1 with every nondeterminism source
/// pinned (no exploration, no replans, no adaptive tiling, breaker off),
/// so batch sequence number == request submit order and logits are a
/// pure function of the weight seed and the image.
fn chaos_cfg(threads: usize, safe_retry: bool) -> ServerConfig {
    ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: 1,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 77,
        threads,
        router: RouterConfig {
            explore_every: 0,
            quarantine_after: 0,
            ..Default::default()
        },
        replan_every: 0,
        adaptive_tiling: false,
        safe_retry,
        ..Default::default()
    }
}

#[test]
fn tile_panic_fails_exactly_the_targeted_request_at_any_pool_size() {
    let _g = chaos_guard();
    let nreq = 6usize;
    let target = 3u64; // batch_seq of the third submitted request
    for threads in [1usize, 4, 8] {
        let mut rng = Rng::new(4000 + threads as u64);
        let imgs: Vec<Vec<f32>> = (0..nreq).map(|_| rng.activation_vec(3 * 16 * 16)).collect();

        let serve = |armed: bool| {
            if armed {
                fault::install(FaultPlan::new(
                    target,
                    vec![
                        FaultSpec {
                            site: SITE_POOL_TILE,
                            ctx: Some(target),
                            kind: FaultKind::TilePanic,
                            sticky: false,
                        },
                        // A one-shot straggler on the batch before it:
                        // timing-only, must never change an outcome.
                        FaultSpec {
                            site: SITE_POOL_TILE,
                            ctx: Some(target - 1),
                            kind: FaultKind::Straggle(Duration::from_millis(2)),
                            sticky: false,
                        },
                    ],
                ));
            } else {
                fault::clear();
            }
            // safe_retry off: the blast-radius property is "exactly the
            // targeted request fails" — no degraded recovery masking it.
            let server = ServerHandle::start(chaos_cfg(threads, false)).unwrap();
            let pending: Vec<_> = imgs
                .iter()
                .map(|img| server.submit(img.clone()).unwrap())
                .collect();
            let outcomes: Vec<Result<Vec<f32>, ServerError>> = pending
                .into_iter()
                .map(|rx| {
                    rx.recv_timeout(Duration::from_secs(120))
                        .expect("response channel")
                        .map(|r| r.logits)
                })
                .collect();
            let fired = fault::fired_count();
            let stats = server.shutdown().unwrap();
            fault::clear();
            (outcomes, fired, stats.snapshot)
        };

        let (baseline, _, base_snap) = serve(false);
        assert!(baseline.iter().all(|o| o.is_ok()), "t{threads}: baseline faulted");
        assert_eq!(base_snap.errors, 0, "t{threads}");

        let (chaos, fired, snap) = serve(true);
        assert_eq!(fired, 2, "t{threads}: planned faults did not all fire");
        for (i, (got, want)) in chaos.iter().zip(&baseline).enumerate() {
            if i as u64 + 1 == target {
                match got {
                    Err(ServerError::Faulted(_)) => {}
                    other => panic!("t{threads}: targeted request got {other:?}"),
                }
            } else {
                // Byte-identical to the un-faulted run — including the
                // straggled request and every request staged after the
                // fault (the rebuilt slot arena must not perturb them).
                assert_eq!(
                    got.as_ref().expect("healthy request failed"),
                    want.as_ref().unwrap(),
                    "t{threads}: request {i} diverged from un-faulted run"
                );
            }
        }
        assert_eq!(snap.errors, 1, "t{threads}");
        assert_eq!(snap.executor_restarts, 1, "t{threads}");
        assert_eq!(snap.responses, (nreq - 1) as u64, "t{threads}");
    }
}

#[test]
fn nan_poison_triggers_safe_path_retry_matching_the_scalar_oracle() {
    let _g = chaos_guard();
    let net = network_by_name("minicnn").unwrap();
    let weight_seed = 77u64;
    let target_idx = 1usize; // second request -> batch_seq (ctx) 2
    for threads in [1usize, 4, 8] {
        let mut rng = Rng::new(5000 + threads as u64);
        let imgs: Vec<Vec<f32>> = (0..4).map(|_| rng.activation_vec(3 * 16 * 16)).collect();

        // The oracle is the degraded path's exact program, built
        // stand-alone: a batch-1 plan with every CONV layer's tile
        // policy pinned to the scalar unblocked oracle and every sparse
        // CONV routed DirectSparse.
        fault::clear();
        let pool = WorkerPool::new(threads);
        let cache = PlanCache::build(&net, weight_seed);
        for l in &net.layers {
            if matches!(&l.kind, LayerKind::Conv(_)) {
                cache.set_tile_policy(&l.name, TilePolicy::unblocked());
            }
        }
        let plan = cache.network_plan(&net, 1, |_, _| Method::DirectSparse);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let mut input = vec![0.0f32; plan.input_dims().len()];
        input[..imgs[target_idx].len()].copy_from_slice(&imgs[target_idx]);
        let oracle = plan.run_with_input(&input, &pool, &mut arena).to_vec();
        drop(pool);

        // Sticky NaN poison on every sconv tile of the targeted batch:
        // conv3 sits after the max-pool, so the poison provably reaches
        // the logits and the pre-retirement finite check.
        fault::install(FaultPlan::new(
            0xBEEF,
            vec![FaultSpec {
                site: SITE_SCONV_TILE,
                ctx: Some(target_idx as u64 + 1),
                kind: FaultKind::PoisonNan,
                sticky: true,
            }],
        ));
        let server = ServerHandle::start(chaos_cfg(threads, true)).unwrap();
        let pending: Vec<_> = imgs
            .iter()
            .map(|img| server.submit(img.clone()).unwrap())
            .collect();
        let logits: Vec<Vec<f32>> = pending
            .into_iter()
            .map(|rx| {
                rx.recv_timeout(Duration::from_secs(120))
                    .expect("response channel")
                    .expect("poisoned slot must recover via the safe path")
                    .logits
            })
            .collect();
        let fired = fault::fired_count();
        let stats = server.shutdown().unwrap();
        fault::clear();

        assert!(fired >= 1, "t{threads}: poison never fired");
        // The finite check tripped exactly once, and the retry answered
        // the request with the oracle's bytes.
        assert_eq!(stats.snapshot.executor_restarts, 1, "t{threads}");
        assert_eq!(stats.snapshot.errors, 0, "t{threads}");
        assert_eq!(stats.snapshot.responses, imgs.len() as u64, "t{threads}");
        assert_eq!(
            logits[target_idx], oracle,
            "t{threads}: safe-path logits diverged from the scalar oracle"
        );
        for (i, l) in logits.iter().enumerate() {
            assert!(
                l.iter().all(|v| v.is_finite()),
                "t{threads}: request {i} leaked a non-finite logit"
            );
        }
    }
}

#[test]
fn circuit_breaker_quarantines_and_reinstates_after_cooldown() {
    let _g = chaos_guard();
    let cfg = ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: 1,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 77,
        threads: 4,
        router: RouterConfig {
            explore_every: 0,
            quarantine_after: 2,
            quarantine_cooldown: 4,
            ..Default::default()
        },
        // Replanning every batch re-asks the router, which is where
        // expired quarantine cooldowns lapse (decision-counted — no
        // wall-clock in the loop).
        replan_every: 1,
        adaptive_tiling: false,
        safe_retry: true,
        ..Default::default()
    };
    // One-shot tile panics on the first two staged batches: enough to
    // hit quarantine_after, never touching later (healthy) batches.
    fault::install(FaultPlan::new(
        7,
        (1..=2)
            .map(|k| FaultSpec {
                site: SITE_POOL_TILE,
                ctx: Some(k),
                kind: FaultKind::TilePanic,
                sticky: false,
            })
            .collect(),
    ));
    let server = ServerHandle::start(cfg).unwrap();
    let mut rng = Rng::new(6000);
    let elems = server.image_elems();

    // Phase 1: two faulted batches — both answered via the safe path.
    for i in 0..2 {
        let resp = server
            .submit(rng.activation_vec(elems))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap_or_else(|e| panic!("faulted request {i} not recovered: {e}"));
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let m = server.metrics();
    assert!(
        m.method_quarantines >= 1,
        "two faults at quarantine_after=2 never tripped the breaker"
    );
    assert_eq!(m.executor_restarts, 2);

    // Phase 2: healthy traffic advances the router's decision counter
    // past the cooldown; the lapsed quarantines must be reinstated.
    fault::clear();
    for _ in 0..16 {
        let resp = server
            .submit(rng.activation_vec(elems))
            .unwrap()
            .recv()
            .unwrap()
            .expect("healthy request failed");
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let m = server.metrics();
    assert!(
        m.method_reinstates >= 1,
        "cooldown never reinstated a quarantined method"
    );
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.snapshot.errors, 0);
    assert_eq!(stats.snapshot.responses, 18);
}
