//! Tables 2 & 3: evaluated platforms and network summaries.

use escoin::bench_harness::{table2_platforms, table3_rows};

fn main() {
    print!("{}", table2_platforms().render());
    println!();
    print!("{}", table3_rows().render());
}
