//! Fig 9: execution-time breakdown of sparse CONV layers into kernels
//! (`im2col`, `sgemm`, `csrmm`, `sconv`, `pad_in`), per model x approach.

use escoin::bench_harness::fig8::Fig8Opts;
use escoin::bench_harness::fig9::fig9_breakdown;
use escoin::bench_harness::{BenchOpts, Table};
use escoin::config::all_networks;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let opts = Fig8Opts {
        batch: env_usize("ESCOIN_BENCH_BATCH", 2),
        spatial_scale: env_usize("ESCOIN_BENCH_SCALE", 1),
        threads: env_usize(
            "ESCOIN_BENCH_THREADS",
            escoin::util::default_threads(),
        ),
        bench: BenchOpts::from_env(),
    };
    eprintln!("fig9: {opts:?}");
    let mut table = Table::new(
        "Fig 9: sparse-CONV execution-time breakdown (fractions per approach)",
        &["model", "approach", "im2col", "sgemm", "csrmm", "sconv", "pad_in", "total"],
    );
    for net in all_networks() {
        for row in fig9_breakdown(&net, opts) {
            table.row(vec![
                row.model.clone(),
                row.approach.to_string(),
                format!("{:.0}%", 100.0 * row.fraction("im2col")),
                format!("{:.0}%", 100.0 * row.fraction("sgemm")),
                format!("{:.0}%", 100.0 * row.fraction("csrmm")),
                format!("{:.0}%", 100.0 * row.fraction("sconv")),
                format!("{:.0}%", 100.0 * row.fraction("pad_in")),
                format!("{:.1?}", row.total()),
            ]);
        }
        eprintln!("  {} done", net.name);
    }
    print!("{}", table.render());
    println!(
        "paper's shape: CUBLAS/CUSPARSE pay the same im2col tax; Escoin pays none \
         and its sconv beats sgemm."
    );
}
