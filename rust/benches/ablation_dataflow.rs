//! Ablation A2 (ours): dataflow / method choices the paper discusses.
//!
//! 1. §3.3 dataflow: what the read-only cache buys sconv — simulated hit
//!    rates with inputs routed through the RO cache vs plain global loads.
//! 2. §3.4 Winograd future work: dense 3x3 layers, winograd vs gemm vs
//!    direct, showing where the F(2x2,3x3) path pays off.

use escoin::bench_harness::{bench_median, BenchOpts, Table};
use escoin::config::ConvShape;
use escoin::conv::{lowered_gemm_parallel, sconv_parallel, winograd_3x3, ConvWeights};
use escoin::simulator::{trace_csrmm, trace_sconv, MemoryHierarchy};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::Rng;

fn main() {
    let threads = escoin::util::default_threads();
    let bench = BenchOpts::from_env();

    // Part 1: cache routing (simulated).
    let shape = ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88);
    let mut rng = Rng::new(0xAB2);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let mut t1 = Table::new(
        "Ablation: §3.3 data placement (simulated, AlexNet conv3 class)",
        &["kernel", "RO hit", "L2 hit", "DRAM bytes"],
    );
    let mut mem = MemoryHierarchy::p100();
    trace_sconv(&shape, &w.stretched_banks()[0], &mut mem);
    let r = mem.report();
    t1.row(vec![
        "sconv (inputs via RO cache)".into(),
        format!("{:.0}%", 100.0 * r.ro_hit_rate()),
        format!("{:.0}%", 100.0 * r.l2_hit_rate()),
        format!("{}", r.dram_bytes),
    ]);
    let mut mem = MemoryHierarchy::p100();
    trace_csrmm(&w.csr_banks()[0], shape.out_h() * shape.out_w(), &mut mem);
    let r = mem.report();
    t1.row(vec![
        "csrmm (lowered matrix)".into(),
        format!("{:.0}%", 100.0 * r.ro_hit_rate()),
        format!("{:.0}%", 100.0 * r.l2_hit_rate()),
        format!("{}", r.dram_bytes),
    ]);
    print!("{}", t1.render());

    // Part 2: Winograd on dense 3x3 layers (§3.4 future work, built).
    let mut t2 = Table::new(
        "Ablation: §3.4 Winograd F(2x2,3x3) on dense 3x3 layers",
        &["layer", "gemm", "winograd", "sconv(dense)", "best"],
    );
    for (name, c, m, hw) in [
        ("resnet conv2-class", 64usize, 64usize, 56usize),
        ("resnet conv4-class", 256, 256, 14),
        ("alexnet conv3-class", 256, 384, 13),
    ] {
        let shape = ConvShape::new(c, m, hw, hw, 3, 3, 1, 1);
        let mut rng = Rng::new(0xAB3);
        let x = Tensor4::random_activations(Dims4::new(1, c, hw, hw), &mut rng);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let st = w.stretched_banks();
        let g = bench_median(bench, || lowered_gemm_parallel(&shape, &x, &w, threads));
        let wg = bench_median(bench, || winograd_3x3(&shape, &x, &w));
        let d = bench_median(bench, || sconv_parallel(&shape, &x, &st, threads));
        let best = [("gemm", g), ("winograd", wg), ("sconv", d)]
            .into_iter()
            .min_by_key(|(_, t)| *t)
            .unwrap()
            .0;
        t2.row(vec![
            name.to_string(),
            format!("{g:.1?}"),
            format!("{wg:.1?}"),
            format!("{d:.1?}"),
            best.to_string(),
        ]);
        eprintln!("  {name} done");
    }
    print!("{}", t2.render());
}
