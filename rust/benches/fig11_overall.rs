//! Fig 11: overall inference speedup (whole-network iteration time),
//! normalised to CUBLAS. Paper: Escoin 1.47x/1.18x/1.19x on P100 and
//! 1.74x/1.34x/1.43x on 1080Ti for AlexNet/GoogLeNet/ResNet; geomean
//! 1.38x over CUBLAS, 1.60x over CUSPARSE.

use escoin::bench_harness::fig11::{fig11_overall, geomean_overall};
use escoin::bench_harness::fig8::Fig8Opts;
use escoin::bench_harness::{BenchOpts, Table};
use escoin::config::all_networks;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let opts = Fig8Opts {
        batch: env_usize("ESCOIN_BENCH_BATCH", 2),
        spatial_scale: env_usize("ESCOIN_BENCH_SCALE", 1),
        threads: env_usize(
            "ESCOIN_BENCH_THREADS",
            escoin::util::default_threads(),
        ),
        bench: BenchOpts::from_env(),
    };
    eprintln!("fig11: {opts:?}");
    let mut table = Table::new(
        "Fig 11: overall inference speedup over CUBLAS (whole iteration)",
        &["model", "CUBLAS", "CUSPARSE", "Escoin", "CUSPARSE x", "Escoin x", "sparse-conv share"],
    );
    let mut rows = Vec::new();
    for net in all_networks() {
        let row = fig11_overall(&net, opts);
        table.row(vec![
            row.model.clone(),
            format!("{:.1?}", row.cublas),
            format!("{:.1?}", row.cusparse),
            format!("{:.1?}", row.escoin),
            format!("{:.2}x", row.speedup_cusparse()),
            format!("{:.2}x", row.speedup_escoin()),
            format!("{:.0}%", 100.0 * row.sparse_conv_fraction),
        ]);
        eprintln!("  {} done", row.model);
        rows.push(row);
    }
    let (cb, cs) = geomean_overall(&rows);
    print!("{}", table.render());
    println!(
        "geomean Escoin overall speedup: {cb:.2}x over CUBLAS (paper 1.38x), \
         {cs:.2}x over CUSPARSE (paper 1.60x)"
    );
}
