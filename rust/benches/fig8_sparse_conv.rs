//! Fig 8: sparse CONV layer speedup, three models x three approaches,
//! normalised to CUBLAS. Regenerates the paper's bar chart as a table.
//!
//! Knobs: ESCOIN_BENCH_BATCH (default 2), ESCOIN_BENCH_SCALE (spatial
//! divisor, default 1 = paper-native shapes), ESCOIN_BENCH_ITERS.

use escoin::bench_harness::fig8::{fig8_sparse_conv, geomean_speedups, Fig8Opts};
use escoin::bench_harness::{BenchOpts, Table};
use escoin::config::all_networks;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let opts = Fig8Opts {
        batch: env_usize("ESCOIN_BENCH_BATCH", 2),
        spatial_scale: env_usize("ESCOIN_BENCH_SCALE", 1),
        threads: env_usize(
            "ESCOIN_BENCH_THREADS",
            escoin::util::default_threads(),
        ),
        bench: BenchOpts::from_env(),
    };
    eprintln!("fig8: {opts:?}");
    let mut table = Table::new(
        "Fig 8: sparse CONV speedup over CUBLAS (paper: Escoin 1.50x-5.57x, avg 2.63x)",
        &["model", "CUBLAS", "CUSPARSE", "Escoin", "CUSPARSE x", "Escoin x"],
    );
    let mut rows = Vec::new();
    for net in all_networks() {
        let row = fig8_sparse_conv(&net, opts);
        table.row(vec![
            row.model.clone(),
            format!("{:.1?}", row.cublas),
            format!("{:.1?}", row.cusparse),
            format!("{:.1?}", row.escoin),
            format!("{:.2}x", row.speedup_cusparse()),
            format!("{:.2}x", row.speedup_escoin()),
        ]);
        eprintln!("  {} done", row.model);
        rows.push(row);
    }
    let (over_cublas, over_cusparse) = geomean_speedups(&rows);
    print!("{}", table.render());
    println!(
        "geomean Escoin speedup: {over_cublas:.2}x over CUBLAS (paper 2.63x), \
         {over_cusparse:.2}x over CUSPARSE (paper 3.07x)"
    );
}
