//! Fig 10: read-only (texture) + L2 cache hit rates of csrmm vs sconv on
//! the three models, from the memory-hierarchy simulator.
//!
//! Paper (P100, nvprof): sconv RO hit 71%-81%, csrmm RO hit 52%-57%;
//! L2 shows the same trend.

use escoin::bench_harness::fig10::{fig10_cache_rates, Fig10Opts};
use escoin::bench_harness::Table;
use escoin::config::all_networks;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let opts = Fig10Opts {
        spatial_scale: env_usize("ESCOIN_BENCH_SCALE", 1),
        max_layers: env_usize("ESCOIN_FIG10_MAX_LAYERS", 0),
    };
    eprintln!("fig10: {opts:?}");
    let mut table = Table::new(
        "Fig 10: simulated cache hit rates (paper: sconv RO 71-81%, csrmm RO 52-57%)",
        &["model", "csrmm RO", "sconv RO", "csrmm L2", "sconv L2"],
    );
    for net in all_networks() {
        let row = fig10_cache_rates(&net, opts);
        table.row(vec![
            row.model.clone(),
            format!("{:.0}%", 100.0 * row.csrmm_ro),
            format!("{:.0}%", 100.0 * row.sconv_ro),
            format!("{:.0}%", 100.0 * row.csrmm_l2),
            format!("{:.0}%", 100.0 * row.sconv_l2),
        ]);
        eprintln!("  {} done", row.model);
    }
    print!("{}", table.render());
}
