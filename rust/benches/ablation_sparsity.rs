//! Ablation A1 (ours): how sparsity level drives the method crossover and
//! the ELL padding overhead.
//!
//! Sweeps a fixed 3x3 layer from dense to 95% sparse and reports each
//! method's time plus the ELL slots/nnz ratio — the design-choice
//! evidence for DESIGN.md (when is direct sparse worth it? how much does
//! the TPU-friendly ELL padding cost?).

use escoin::bench_harness::{bench_median, BenchOpts, Table};
use escoin::config::ConvShape;
use escoin::conv::{lowered_gemm_parallel, lowered_spmm_parallel, sconv_parallel, ConvWeights};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::Rng;

fn main() {
    let threads = escoin::util::default_threads();
    let bench = BenchOpts::from_env();
    let mut table = Table::new(
        "Ablation: sparsity sweep on a ResNet conv4-class layer (256c 3x3 @14x14, batch 4)",
        &["sparsity", "gemm", "spmm", "sconv", "best", "ELL slots/nnz"],
    );
    for sparsity in [0.0, 0.3, 0.5, 0.7, 0.8, 0.9, 0.95] {
        let mut shape = ConvShape::new(256, 256, 14, 14, 3, 3, 1, 1);
        if sparsity > 0.0 {
            shape = shape.with_sparsity(sparsity);
        }
        let mut rng = Rng::new(0xAB1);
        let x = Tensor4::random_activations(Dims4::new(4, shape.c, shape.h, shape.w), &mut rng);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let banks = w.csr_banks();
        let st = w.stretched_banks();
        let ell = &w.ell_banks(8)[0];
        let g = bench_median(bench, || lowered_gemm_parallel(&shape, &x, &w, threads));
        let s = bench_median(bench, || lowered_spmm_parallel(&shape, &x, &banks, threads));
        let d = bench_median(bench, || sconv_parallel(&shape, &x, &st, threads));
        let best = [("gemm", g), ("spmm", s), ("sconv", d)]
            .into_iter()
            .min_by_key(|(_, t)| *t)
            .unwrap()
            .0;
        table.row(vec![
            format!("{sparsity:.2}"),
            format!("{g:.1?}"),
            format!("{s:.1?}"),
            format!("{d:.1?}"),
            best.to_string(),
            format!("{:.2}", ell.padding_overhead()),
        ]);
        eprintln!("  sparsity {sparsity} done");
    }
    print!("{}", table.render());
    println!(
        "shape: on the paper's GPUs gemm wins the dense end; on this CPU testbed \\
         the register-blocked direct kernel wins throughout, with spmm closing \\
         in at extreme sparsity — see EXPERIMENTS.md A1."
    );
}
