"""L2 model tests: every method of every artifact layer agrees with the
dense-conv oracle, and the MiniCNN forward is method-invariant."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import (
    ARTIFACT_BATCH,
    ARTIFACT_LAYERS,
    METHODS,
    MINICNN_BATCH,
    MINICNN_CLASSES,
    MINICNN_LAYERS,
    dense_to_ell,
    stretch_colidx,
    synthetic_weights,
)
from compile.kernels import ref
from compile.model import conv_layer_fn, minicnn_fn


def _layer_args(shape, method, dw):
    if method == "gemm":
        return (jnp.asarray(dw),)
    vals, idx = dense_to_ell(dw, shape.ell_k())
    if method == "sconv":
        idx = stretch_colidx(idx, shape)
    return (jnp.asarray(vals), jnp.asarray(idx))


@pytest.mark.parametrize("layer_name", list(ARTIFACT_LAYERS))
@pytest.mark.parametrize("method", METHODS)
def test_artifact_layer_matches_oracle(layer_name, method):
    shape = ARTIFACT_LAYERS[layer_name]
    rng = np.random.default_rng(hash(layer_name) % 2**31)
    x = jnp.asarray(
        rng.standard_normal((ARTIFACT_BATCH, shape.c, shape.h, shape.w)).astype(np.float32)
    )
    dw = synthetic_weights(shape, 42)
    fn = conv_layer_fn(shape, method)
    got = fn(x, *_layer_args(shape, method, dw))
    want = ref.sconv_ref(x, dw, shape)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("method", METHODS)
def test_methods_agree_pairwise(method):
    # All three methods compute the same function; compare against gemm.
    shape = ARTIFACT_LAYERS["alexnet_conv3"]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, shape.c, shape.h, shape.w)).astype(np.float32))
    dw = synthetic_weights(shape, 7)
    base = conv_layer_fn(shape, "gemm")(x, *_layer_args(shape, "gemm", dw))
    got = conv_layer_fn(shape, method)(x, *_layer_args(shape, method, dw))
    np.testing.assert_allclose(got, base, rtol=1e-3, atol=1e-3)


def _minicnn_weights(seed=11):
    l1, l2, l3 = MINICNN_LAYERS
    rng = np.random.default_rng(seed)
    w1 = synthetic_weights(l1, seed)
    dw2 = synthetic_weights(l2, seed + 1)
    dw3 = synthetic_weights(l3, seed + 2)
    fc_w = rng.standard_normal((l3.m, MINICNN_CLASSES)).astype(np.float32) * 0.1
    fc_b = rng.standard_normal(MINICNN_CLASSES).astype(np.float32) * 0.01
    return w1, dw2, dw3, fc_w, fc_b


def _minicnn_args(method, w1, dw2, dw3, fc_w, fc_b):
    l1, l2, l3 = MINICNN_LAYERS
    if method == "gemm":
        return (
            jnp.asarray(w1), jnp.asarray(dw2), jnp.asarray(dw3),
            jnp.asarray(fc_w), jnp.asarray(fc_b),
        )
    v2, i2 = dense_to_ell(dw2, l2.ell_k())
    v3, i3 = dense_to_ell(dw3, l3.ell_k())
    if method == "sconv":
        i2 = stretch_colidx(i2, l2)
        i3 = stretch_colidx(i3, l3)
    return (
        jnp.asarray(w1), jnp.asarray(v2), jnp.asarray(i2), jnp.asarray(v3), jnp.asarray(i3),
        jnp.asarray(fc_w), jnp.asarray(fc_b),
    )


def test_minicnn_methods_agree():
    rng = np.random.default_rng(3)
    l1 = MINICNN_LAYERS[0]
    x = jnp.asarray(
        rng.standard_normal((MINICNN_BATCH, l1.c, l1.h, l1.w)).astype(np.float32)
    )
    weights = _minicnn_weights()
    outs = {
        m: minicnn_fn(m)(x, *_minicnn_args(m, *weights)) for m in METHODS
    }
    np.testing.assert_allclose(outs["spmm"], outs["gemm"], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(outs["sconv"], outs["gemm"], rtol=1e-3, atol=1e-3)
    assert outs["gemm"].shape == (MINICNN_BATCH, MINICNN_CLASSES)


def test_minicnn_spatial_chain():
    # 32 -> pool -> 16 -> pool -> 8: the config table must agree.
    l1, l2, l3 = MINICNN_LAYERS
    assert l1.out_h == 32 and l2.h == 16 and l3.h == 8
    assert l2.c == l1.m and l3.c == l2.m


def test_minicnn_relu_nonnegativity_flows_through():
    # Intermediate activations after ReLU must be non-negative; the head
    # (GAP + linear) may be signed. Checks the model composition wiring.
    import jax
    rng = np.random.default_rng(5)
    l1 = MINICNN_LAYERS[0]
    x = jnp.asarray(rng.standard_normal((2, l1.c, l1.h, l1.w)).astype(np.float32))
    weights = _minicnn_weights(21)
    logits = minicnn_fn("sconv")(x, *_minicnn_args("sconv", *weights))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_minicnn_batch_rows_independent():
    # Row n of the logits depends only on image n (batching correctness
    # the serving padder relies on).
    rng = np.random.default_rng(6)
    l1 = MINICNN_LAYERS[0]
    base = rng.standard_normal((MINICNN_BATCH, l1.c, l1.h, l1.w)).astype(np.float32)
    weights = _minicnn_weights(22)
    fn = minicnn_fn("sconv")
    args = _minicnn_args("sconv", *weights)
    full = np.asarray(fn(jnp.asarray(base), *args))
    # Zero every other image; row 0 must not move.
    perturbed = base.copy()
    perturbed[1:] = 0.0
    part = np.asarray(fn(jnp.asarray(perturbed), *args))
    np.testing.assert_allclose(full[0], part[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", METHODS)
def test_strided_artifact_layer_matches_oracle(method):
    # The stride-2 artifact class end to end per method.
    shape = ARTIFACT_LAYERS["resnet_conv3_s2"]
    rng = np.random.default_rng(9)
    x = jnp.asarray(
        rng.standard_normal((ARTIFACT_BATCH, shape.c, shape.h, shape.w)).astype(np.float32)
    )
    dw = synthetic_weights(shape, 77)
    got = conv_layer_fn(shape, method)(x, *_layer_args(shape, method, dw))
    want = ref.sconv_ref(x, dw, shape)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
