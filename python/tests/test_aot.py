"""AOT pipeline tests: manifest integrity and HLO-text sanity.

These validate the artifacts directory if it exists (built by
``make artifacts``); the lowering functions themselves are exercised
directly on one small artifact so the test runs even on a fresh tree.
"""

from __future__ import annotations

import json
import os

import pytest

from compile.aot import layer_artifact, to_hlo_text
from compile.configs import ARTIFACT_LAYERS, METHODS, MINICNN_LAYERS, ConvShape

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_layer_artifact_entry_schema():
    name = "alexnet_conv3"
    shape = ARTIFACT_LAYERS[name]
    entry, text = layer_artifact(name, shape, "sconv", batch=2)
    assert entry["name"] == "alexnet_conv3_sconv"
    assert entry["kind"] == "layer"
    assert entry["ell_k"] == shape.ell_k()
    assert entry["output"] == [2, shape.m, shape.out_h, shape.out_w]
    roles = [i["role"] for i in entry["inputs"]]
    assert roles == ["activations", "ell_values", "ell_colidx_stretched"]
    # HLO text sanity: parseable header + parameters of the right arity.
    assert text.startswith("HloModule"), text[:50]
    assert text.count("parameter(") >= 3


def test_gemm_artifact_has_dense_weights_role():
    shape = ConvShape(c=4, m=8, h=6, w=6, r=3, s=3, pad=1, sparsity=0.5)
    entry, text = layer_artifact("tiny", shape, "gemm", batch=1)
    roles = [i["role"] for i in entry["inputs"]]
    assert roles == ["activations", "weights_dense"]
    assert entry["ell_k"] == 0
    assert "HloModule" in text


def test_spmm_artifact_uses_canonical_colidx():
    shape = ConvShape(c=4, m=8, h=6, w=6, r=3, s=3, pad=1, sparsity=0.5)
    entry, _ = layer_artifact("tiny", shape, "spmm", batch=1)
    roles = [i["role"] for i in entry["inputs"]]
    assert roles == ["activations", "ell_values", "ell_colidx_canonical"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltManifest:
    @property
    def manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_full_artifact_set_present(self):
        names = {a["name"] for a in self.manifest["artifacts"]}
        for layer in ARTIFACT_LAYERS:
            for method in METHODS:
                assert f"{layer}_{method}" in names
        for method in METHODS:
            assert f"minicnn_{method}" in names

    def test_hlo_files_exist_and_nonempty(self):
        for a in self.manifest["artifacts"]:
            path = os.path.join(ARTIFACT_DIR, a["file"])
            assert os.path.getsize(path) > 1000, a["name"]
            with open(path) as f:
                assert f.read(9) == "HloModule"

    def test_ell_k_matches_config(self):
        for a in self.manifest["artifacts"]:
            if a["kind"] == "layer" and a["method"] != "gemm":
                shape = ARTIFACT_LAYERS[a["layer"]]
                assert a["ell_k"] == shape.ell_k(), a["name"]

    def test_minicnn_layers_match_config(self):
        for a in self.manifest["artifacts"]:
            if a["kind"] == "model":
                assert len(a["layers"]) == len(MINICNN_LAYERS)
                for got, want in zip(a["layers"], MINICNN_LAYERS):
                    assert got["c"] == want.c and got["m"] == want.m

    def test_input_shapes_are_positive(self):
        for a in self.manifest["artifacts"]:
            for i in a["inputs"]:
                assert all(d > 0 for d in i["shape"]), (a["name"], i)
