"""Kernel vs ref allclose — the CORE correctness signal (L1).

Hypothesis sweeps shapes/strides/pads/sparsities for every Pallas kernel
against its pure-jnp oracle in ``compile.kernels.ref``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import (
    ConvShape,
    dense_to_ell,
    prune_magnitude,
    stretch_colidx,
    synthetic_weights,
)
from compile.kernels import gemm, im2col, pad, ref, sconv, spmm

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def conv_shapes(draw, stride_choices=(1, 2)):
    r = draw(st.sampled_from([1, 3, 5]))
    s = r  # square filters, like every evaluated network
    stride = draw(st.sampled_from(stride_choices))
    pad_amt = draw(st.integers(0, (r - 1) // 2 + 1)) if r > 1 else 0
    c = draw(st.integers(1, 6))
    m = draw(st.integers(1, 8))
    # input must be at least as large as the (unpadded) filter reach
    h = draw(st.integers(max(r, 3), 10))
    w = draw(st.integers(max(s, 3), 10))
    sparsity = draw(st.sampled_from([0.0, 0.5, 0.8, 0.95]))
    return ConvShape(c=c, m=m, h=h, w=w, r=r, s=s, stride=stride, pad=pad_amt, sparsity=sparsity)


def _case(shape: ConvShape, seed: int, batch: int = 2):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, shape.c, shape.h, shape.w)).astype(np.float32))
    dw = synthetic_weights(shape, seed + 1)
    return x, dw


class TestPad:
    @given(conv_shapes(), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_matches_jnp_pad(self, shape, seed):
        x, _ = _case(shape, seed)
        got = pad.pad_input(x, shape.pad)
        want = ref.pad_ref(x, shape.pad)
        np.testing.assert_allclose(got, want)

    def test_zero_pad_identity(self):
        shape = ConvShape(c=2, m=2, h=4, w=4, r=3, s=3)
        x, _ = _case(shape, 0)
        assert pad.pad_input(x, 0) is x

    def test_border_is_zero(self):
        shape = ConvShape(c=1, m=1, h=3, w=3, r=3, s=3, pad=2)
        x, _ = _case(shape, 1)
        xp = pad.pad_input(x, 2)
        assert float(jnp.abs(xp[:, :, :2, :]).max()) == 0.0
        assert float(jnp.abs(xp[:, :, :, -2:]).max()) == 0.0


class TestSconv:
    @given(conv_shapes(), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_matches_dense_conv(self, shape, seed):
        x, dw = _case(shape, seed)
        k = shape.ell_k()
        vals, idx = dense_to_ell(dw, k)
        sidx = stretch_colidx(idx, shape)
        xp = pad.pad_input(x, shape.pad)
        got = sconv.sconv(xp, jnp.asarray(vals), jnp.asarray(sidx), shape)
        want = ref.sconv_ref(x, dw, shape)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_all_zero_weights(self):
        shape = ConvShape(c=2, m=3, h=5, w=5, r=3, s=3, pad=1, sparsity=0.9)
        x, _ = _case(shape, 3)
        vals = jnp.zeros((shape.m, 8), jnp.float32)
        idx = jnp.zeros((shape.m, 8), jnp.int32)
        y = sconv.sconv(pad.pad_input(x, 1), vals, idx, shape)
        assert float(jnp.abs(y).max()) == 0.0

    def test_batch_independence(self):
        shape = ConvShape(c=2, m=2, h=5, w=5, r=3, s=3, pad=1, sparsity=0.5)
        x, dw = _case(shape, 4, batch=3)
        vals, idx = dense_to_ell(dw, shape.ell_k())
        sidx = stretch_colidx(idx, shape)
        xp = pad.pad_input(x, 1)
        y = sconv.sconv(xp, jnp.asarray(vals), jnp.asarray(sidx), shape)
        y1 = sconv.sconv(xp[1:2], jnp.asarray(vals), jnp.asarray(sidx), shape)
        np.testing.assert_allclose(y[1:2], y1, rtol=1e-5, atol=1e-6)

    def test_padding_slots_are_inert(self):
        # Doubling K (all extra slots zero) must not change the result.
        shape = ConvShape(c=2, m=3, h=6, w=6, r=3, s=3, pad=1, sparsity=0.7)
        x, dw = _case(shape, 5)
        k = shape.ell_k()
        v1, i1 = dense_to_ell(dw, k)
        v2, i2 = dense_to_ell(dw, 2 * k)
        xp = pad.pad_input(x, 1)
        y1 = sconv.sconv(xp, jnp.asarray(v1), jnp.asarray(stretch_colidx(i1, shape)), shape)
        y2 = sconv.sconv(xp, jnp.asarray(v2), jnp.asarray(stretch_colidx(i2, shape)), shape)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


class TestIm2col:
    @given(conv_shapes(), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_matches_ref(self, shape, seed):
        x, _ = _case(shape, seed)
        xp = pad.pad_input(x, shape.pad)
        got = im2col.im2col(xp, shape)
        want = ref.im2col_ref(xp, shape.r, shape.s, shape.stride, shape.out_h, shape.out_w)
        np.testing.assert_allclose(got, want)

    def test_duplication_factor(self):
        # Interior elements appear R*S times in the lowered matrix — the
        # paper's bandwidth-waste argument (Fig 2).
        shape = ConvShape(c=1, m=1, h=6, w=6, r=3, s=3, pad=0)
        x, _ = _case(shape, 7, batch=1)
        low = np.asarray(im2col.im2col(pad.pad_input(x, 0), shape))
        centre = float(x[0, 0, 3, 3])
        assert (np.isclose(low, centre)).sum() >= 9


class TestGemm:
    @given(
        st.integers(1, 8),
        st.integers(1, 32),
        st.integers(1, 24),
        st.integers(1, 3),
        st.integers(0, 10_000),
    )
    @settings(**SETTINGS)
    def test_matches_einsum(self, m, k, l, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((n, k, l)).astype(np.float32))
        got = gemm.matmul(a, b)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_identity(self):
        a = jnp.eye(4, dtype=jnp.float32)
        b = jnp.arange(2 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 3)
        np.testing.assert_allclose(gemm.matmul(a, b), b)


class TestSpmm:
    @given(
        st.integers(1, 8),
        st.integers(2, 30),
        st.integers(1, 20),
        st.integers(1, 3),
        st.sampled_from([0.0, 0.5, 0.9]),
        st.integers(0, 10_000),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, m, crs, l, n, sparsity, seed):
        rng = np.random.default_rng(seed)
        dense = rng.standard_normal((m, crs)).astype(np.float32)
        if sparsity:
            dense = prune_magnitude(dense, sparsity)
        k = max(1, int(np.count_nonzero(dense, axis=1).max()))
        vals, idx = dense_to_ell(dense, k)
        b = jnp.asarray(rng.standard_normal((n, crs, l)).astype(np.float32))
        got = spmm.ell_spmm(jnp.asarray(vals), jnp.asarray(idx), b)
        want = ref.ell_spmm_ref(jnp.asarray(vals), jnp.asarray(idx), b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        # and against the dense product
        dense_want = ref.matmul_ref(jnp.asarray(dense), b)
        np.testing.assert_allclose(got, dense_want, rtol=1e-4, atol=1e-4)


class TestFormatHelpers:
    @given(st.integers(1, 10), st.integers(1, 40), st.sampled_from([0.0, 0.3, 0.8]), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_ell_roundtrip(self, rows, cols, sparsity, seed):
        rng = np.random.default_rng(seed)
        dense = rng.standard_normal((rows, cols)).astype(np.float32)
        if sparsity:
            dense = prune_magnitude(dense, sparsity)
        k = max(1, int(np.count_nonzero(dense, axis=1).max()))
        vals, idx = dense_to_ell(dense, k)
        rebuilt = np.zeros_like(dense)
        for i in range(rows):
            for slot in range(k):
                if vals[i, slot] != 0.0:
                    rebuilt[i, idx[i, slot]] = vals[i, slot]
        np.testing.assert_allclose(rebuilt, dense)

    def test_stretch_matches_rust_formula(self):
        # (c, r, s) -> c*Hp*Wp + r*Wp + s, same as rust stretch_weights.
        shape = ConvShape(c=2, m=1, h=4, w=4, r=3, s=3, pad=1)
        colidx = np.array([[15]], dtype=np.int32)  # c=1, r=2, s=0
        got = stretch_colidx(colidx, shape)
        assert got[0, 0] == 1 * 36 + 2 * 6 + 0

    def test_prune_exact_count(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal(1000).astype(np.float32)
        p = prune_magnitude(w, 0.85)
        assert np.count_nonzero(p) == 150
