"""AOT pipeline: lower every layer/model executable to HLO **text** and
write ``artifacts/manifest.json`` for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import (
    ARTIFACT_BATCH,
    ARTIFACT_LAYERS,
    METHODS,
    MINICNN_BATCH,
    MINICNN_CLASSES,
    MINICNN_LAYERS,
    ConvShape,
)
from .model import conv_layer_fn, minicnn_fn


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: tuple[int, ...], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_dict(s: ConvShape) -> dict:
    return {
        "c": s.c,
        "m": s.m,
        "h": s.h,
        "w": s.w,
        "r": s.r,
        "s": s.s,
        "stride": s.stride,
        "pad": s.pad,
        "sparsity": s.sparsity,
    }


def _input_entry(name: str, role: str, spec: jax.ShapeDtypeStruct) -> dict:
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[spec.dtype]
    return {"name": name, "role": role, "shape": list(spec.shape), "dtype": dt}


def layer_artifact(name: str, shape: ConvShape, method: str, batch: int) -> tuple[dict, str]:
    """Lower one CONV-layer executable; returns (manifest entry, hlo text)."""
    k = shape.ell_k()
    x = _spec((batch, shape.c, shape.h, shape.w))
    out_shape = [batch, shape.m, shape.out_h, shape.out_w]
    fn = conv_layer_fn(shape, method)
    if method == "gemm":
        w = _spec((shape.m, shape.crs))
        lowered = jax.jit(fn).lower(x, w)
        inputs = [
            _input_entry("x", "activations", x),
            _input_entry("weights", "weights_dense", w),
        ]
    else:
        vals = _spec((shape.m, k))
        idx = _spec((shape.m, k), jnp.int32)
        lowered = jax.jit(fn).lower(x, vals, idx)
        colidx_role = "ell_colidx_stretched" if method == "sconv" else "ell_colidx_canonical"
        inputs = [
            _input_entry("x", "activations", x),
            _input_entry("values", "ell_values", vals),
            _input_entry("colidx", colidx_role, idx),
        ]
    entry = {
        "name": f"{name}_{method}",
        "kind": "layer",
        "method": method,
        "layer": name,
        "batch": batch,
        "shape": _shape_dict(shape),
        "ell_k": k if method != "gemm" else 0,
        "inputs": inputs,
        "output": out_shape,
        "file": f"{name}_{method}.hlo.txt",
    }
    return entry, to_hlo_text(lowered)


def minicnn_artifact(method: str) -> tuple[dict, str]:
    """Lower the whole MiniCNN forward under ``method``."""
    l1, l2, l3 = MINICNN_LAYERS
    n = MINICNN_BATCH
    x = _spec((n, l1.c, l1.h, l1.w))
    w1 = _spec((l1.m, l1.crs))
    fc_w = _spec((l3.m, MINICNN_CLASSES))
    fc_b = _spec((MINICNN_CLASSES,))

    fn = minicnn_fn(method)
    colrole = "ell_colidx_stretched" if method == "sconv" else "ell_colidx_canonical"
    if method == "gemm":
        w2 = _spec((l2.m, l2.crs))
        w3 = _spec((l3.m, l3.crs))
        lowered = jax.jit(fn).lower(x, w1, w2, w3, fc_w, fc_b)
        weight_inputs = [
            _input_entry("w2", "weights_dense", w2),
            _input_entry("w3", "weights_dense", w3),
        ]
    else:
        v2 = _spec((l2.m, l2.ell_k()))
        i2 = _spec((l2.m, l2.ell_k()), jnp.int32)
        v3 = _spec((l3.m, l3.ell_k()))
        i3 = _spec((l3.m, l3.ell_k()), jnp.int32)
        lowered = jax.jit(fn).lower(x, w1, v2, i2, v3, i3, fc_w, fc_b)
        weight_inputs = [
            _input_entry("v2", "ell_values", v2),
            _input_entry("i2", colrole, i2),
            _input_entry("v3", "ell_values", v3),
            _input_entry("i3", colrole, i3),
        ]
    entry = {
        "name": f"minicnn_{method}",
        "kind": "model",
        "method": method,
        "layer": "minicnn",
        "batch": n,
        "layers": [_shape_dict(l) for l in (l1, l2, l3)],
        "ell_k": [0 if method == "gemm" else l.ell_k() for l in (l2, l3)],
        "inputs": [
            _input_entry("x", "activations", x),
            _input_entry("w1", "weights_dense", w1),
            *weight_inputs,
            _input_entry("fc_w", "weights_dense", fc_w),
            _input_entry("fc_b", "weights_dense", fc_b),
        ],
        "output": [n, MINICNN_CLASSES],
        "file": f"minicnn_{method}.hlo.txt",
    }
    return entry, to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact name prefixes to (re)build",
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    only = args.only.split(",") if args.only else None

    entries = []
    jobs: list[tuple[str, object]] = []
    for name, shape in ARTIFACT_LAYERS.items():
        for method in METHODS:
            jobs.append((f"{name}_{method}", (name, shape, method)))
    for method in METHODS:
        jobs.append((f"minicnn_{method}", ("minicnn", None, method)))

    for art_name, job in jobs:
        if only and not any(art_name.startswith(p) for p in only):
            continue
        name, shape, method = job
        if name == "minicnn":
            entry, text = minicnn_artifact(method)
        else:
            entry, text = layer_artifact(name, shape, method, ARTIFACT_BATCH)
        path = os.path.join(args.outdir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(entry)
        print(f"lowered {entry['name']:32s} -> {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.outdir, "manifest.json")
    if only and os.path.exists(manifest_path):
        # Partial rebuild: merge into the existing manifest by name.
        with open(manifest_path) as f:
            old = {e["name"]: e for e in json.load(f)["artifacts"]}
        for e in entries:
            old[e["name"]] = e
        entries = list(old.values())
    manifest = {"version": 1, "artifacts": entries}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
