"""Layer shapes and sparse-format helpers shared by the compile path.

Mirrors the Rust `config`/`sparse` modules:

* :class:`ConvShape` — the paper's Table 1 shape parameters.
* :func:`prune_magnitude` / :func:`dense_to_ell` / :func:`stretch_colidx`
  — the same pruning + CSR->ELL + weight-stretching pipeline as
  ``rust/src/sparse/``, so an ELL tensor built in Rust at runtime is
  bit-compatible with what the AOT-lowered kernels expect.
* :data:`ARTIFACT_LAYERS` — the layer executables ``aot.py`` lowers.
  Interpret-mode Pallas cannot run batch-128 ImageNet layers on CPU, so
  these are channel/spatially scaled versions of the paper's sparse CONV
  layers (documented in DESIGN.md §7); the *structure* (filter size,
  stride, padding, sparsity) is preserved.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ConvShape:
    """Geometry of one CONV layer (paper Table 1). Groups are handled at
    the model level (the kernels see one group at a time)."""

    c: int
    m: int
    h: int
    w: int
    r: int
    s: int
    stride: int = 1
    pad: int = 0
    sparsity: float = 0.0

    @property
    def out_h(self) -> int:
        return (self.h + 2 * self.pad - self.r) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.w + 2 * self.pad - self.s) // self.stride + 1

    @property
    def padded_h(self) -> int:
        return self.h + 2 * self.pad

    @property
    def padded_w(self) -> int:
        return self.w + 2 * self.pad

    @property
    def weights(self) -> int:
        return self.m * self.c * self.r * self.s

    @property
    def crs(self) -> int:
        return self.c * self.r * self.s

    @property
    def ef(self) -> int:
        return self.out_h * self.out_w

    def nnz_per_row(self) -> int:
        """Exact per-row nonzero count under per-row pruning."""
        return self.crs - int(round(self.crs * self.sparsity))

    def ell_k(self, align: int = 8) -> int:
        """Static ELL slot budget per filter row (DESIGN.md §6).

        Weights are pruned *per row* (each filter keeps its
        ``crs - round(crs*sparsity)`` largest-magnitude taps), so the row
        population is exact and the ELL shape is static — the property the
        TPU adaptation needs. ``k`` is that count rounded up to ``align``.
        """
        k = max(1, self.nnz_per_row())
        return ((k + align - 1) // align) * align


def prune_magnitude(dense: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero the smallest-magnitude entries globally; same semantics as Rust
    ``prune_magnitude`` (exact count via order statistic)."""
    flat = dense.reshape(-1).copy()
    zeros = int(round(flat.size * sparsity))
    if zeros > 0:
        order = np.argsort(np.abs(flat), kind="stable")
        flat[order[:zeros]] = 0.0
    return flat.reshape(dense.shape)


def prune_per_row(dense_rows: np.ndarray, sparsity: float) -> np.ndarray:
    """Per-row magnitude pruning: every row keeps its
    ``cols - round(cols*sparsity)`` largest-magnitude entries.

    This is the pruning model used for all synthetic filter banks (Rust
    ``prune_magnitude`` applied row-wise): it matches global pruning in
    expectation for i.i.d. weights while giving the exact static row
    population the ELL/TPU format requires (DESIGN.md §6).
    """
    out = dense_rows.copy()
    for i in range(out.shape[0]):
        out[i] = prune_magnitude(out[i], sparsity)
    return out


def dense_to_ell(dense_rows: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Convert a dense ``(rows, cols)`` matrix to ELL ``(rows, k)`` arrays
    ``(values f32, colidx int32)``. Rows are scanned left to right (CSR
    order); padding slots hold value 0.0 / column 0. Asserts every row
    fits in ``k`` slots — the same contract the Rust runtime enforces."""
    rows, _cols = dense_rows.shape
    values = np.zeros((rows, k), dtype=np.float32)
    colidx = np.zeros((rows, k), dtype=np.int32)
    for i in range(rows):
        nz = np.nonzero(dense_rows[i])[0]
        assert len(nz) <= k, f"row {i} has {len(nz)} nonzeros > ELL k={k}"
        values[i, : len(nz)] = dense_rows[i, nz]
        colidx[i, : len(nz)] = nz
    return values, colidx


def stretch_colidx(colidx: np.ndarray, shape: ConvShape) -> np.ndarray:
    """Weight stretching (paper §3.1): canonical filter column
    ``(c, r, s)`` -> flat offset ``c*Hp*Wp + r*Wp + s`` into the padded
    image. Identical to Rust ``stretch_weights``."""
    rs = shape.r * shape.s
    c = colidx // rs
    r = (colidx // shape.s) % shape.r
    s = colidx % shape.s
    return (c * shape.padded_h * shape.padded_w + r * shape.padded_w + s).astype(np.int32)


def synthetic_weights(shape: ConvShape, seed: int) -> np.ndarray:
    """Normal-initialised ``(M, C*R*S)`` filter bank pruned to
    ``shape.sparsity`` — the DESIGN.md §7 stand-in for SkimCaffe models."""
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((shape.m, shape.crs)).astype(np.float32)
    if shape.sparsity > 0.0:
        dense = prune_per_row(dense, shape.sparsity)
    return dense


# ---------------------------------------------------------------------------
# AOT artifact set.
#
# Scaled stand-ins for the paper's sparse CONV layer classes. Names encode
# provenance: the paper layer each one is modelled on. Batch sizes are
# small because interpret-mode Pallas executes the kernel body as lowered
# HLO loops on CPU.
# ---------------------------------------------------------------------------

ARTIFACT_BATCH = 2

ARTIFACT_LAYERS: dict[str, ConvShape] = {
    # AlexNet conv2 class: 5x5 pad-2 (channels /8, spatial /2).
    "alexnet_conv2": ConvShape(c=12, m=32, h=13, w=13, r=5, s=5, stride=1, pad=2, sparsity=0.85),
    # AlexNet conv3 class: 3x3 pad-1 at native 13x13 (channels /8).
    "alexnet_conv3": ConvShape(c=32, m=48, h=13, w=13, r=3, s=3, stride=1, pad=1, sparsity=0.88),
    # GoogLeNet inception 5x5 branch class (4e geometry, channels /2).
    "googlenet_inc4e_5x5": ConvShape(c=16, m=64, h=14, w=14, r=5, s=5, stride=1, pad=2, sparsity=0.84),
    # ResNet conv4_x 3x3 class at native 14x14 (channels /8).
    "resnet_conv4_3x3": ConvShape(c=32, m=32, h=14, w=14, r=3, s=3, stride=1, pad=1, sparsity=0.78),
    # ResNet strided 3x3 (first block of a stage), exercises stride=2.
    "resnet_conv3_s2": ConvShape(c=16, m=16, h=16, w=16, r=3, s=3, stride=2, pad=1, sparsity=0.74),
}

#: Methods lowered for each layer (the paper's three contenders).
METHODS = ("gemm", "spmm", "sconv")

#: The MiniCNN served by the end-to-end example (CIFAR-scale).
MINICNN_LAYERS: list[ConvShape] = [
    ConvShape(c=3, m=16, h=32, w=32, r=3, s=3, stride=1, pad=1, sparsity=0.0),
    ConvShape(c=16, m=32, h=16, w=16, r=3, s=3, stride=1, pad=1, sparsity=0.80),
    ConvShape(c=32, m=64, h=8, w=8, r=3, s=3, stride=1, pad=1, sparsity=0.80),
]
MINICNN_CLASSES = 10
MINICNN_BATCH = 4
