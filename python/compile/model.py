"""L2: JAX layer/model builders composing the L1 Pallas kernels.

Python exists only on the compile path: each builder returns a jax
function that ``aot.py`` lowers once to HLO text; the Rust coordinator
executes the compiled artifact at serve time.

Three executable *methods* per CONV layer — the paper's contenders:

* ``gemm``  — ``pad -> im2col -> dense matmul`` (CUBLAS proxy; pruned
  weights stay dense, zeros included).
* ``spmm``  — ``pad -> im2col -> ELL spmm`` (CUSPARSE proxy; canonical
  column ids into the lowered matrix).
* ``sconv`` — ``pad -> direct sparse conv`` (Escoin; weight-stretched
  offsets, no lowered matrix ever materialised).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .configs import ConvShape, MINICNN_CLASSES, MINICNN_LAYERS
from .kernels import gemm, im2col, pad, sconv, spmm


def conv_layer_fn(shape: ConvShape, method: str) -> Callable:
    """Build the jax function for one CONV layer under ``method``.

    Signatures (all return (N, M, E, F)):

    * gemm:  ``fn(x, weights)`` with ``weights`` (M, C*R*S) dense.
    * spmm:  ``fn(x, values, colidx)`` with canonical ELL (M, K).
    * sconv: ``fn(x, values, colidx)`` with stretched ELL (M, K).
    """
    if method == "gemm":

        def fn_gemm(x, weights):
            xp = pad.pad_input(x, shape.pad)
            lowered = im2col.im2col(xp, shape)  # the lowering overhead
            y = gemm.matmul(weights, lowered)
            return y.reshape(x.shape[0], shape.m, shape.out_h, shape.out_w)

        return fn_gemm

    if method == "spmm":

        def fn_spmm(x, values, colidx):
            xp = pad.pad_input(x, shape.pad)
            lowered = im2col.im2col(xp, shape)  # same lowering overhead
            y = spmm.ell_spmm(values, colidx, lowered)
            return y.reshape(x.shape[0], shape.m, shape.out_h, shape.out_w)

        return fn_spmm

    if method == "sconv":

        def fn_sconv(x, values, colidx):
            xp = pad.pad_input(x, shape.pad)  # pad_in — no im2col
            return sconv.sconv(xp, values, colidx, shape)

        return fn_sconv

    raise ValueError(f"unknown method {method!r}")


def _maxpool2x2(x: jax.Array) -> jax.Array:
    """2x2/2 max pool (NCHW) used between MiniCNN stages."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def minicnn_fn(method: str) -> Callable:
    """Whole-model forward for the E2E serving example (CIFAR-scale).

    Layer 1 is dense (always the gemm path, like the paper keeping conv1
    dense); layers 2-3 are pruned and use ``method``. Head: global average
    pool + linear classifier.

    Signature — gemm: ``fn(x, w1, w2, w3, fc_w, fc_b)`` with dense
    (M, CRS) filter matrices; spmm/sconv:
    ``fn(x, w1, v2, i2, v3, i3, fc_w, fc_b)`` with ELL (values, colidx)
    pairs (canonical ids for spmm, stretched offsets for sconv).
    """
    l1, l2, l3 = MINICNN_LAYERS
    conv1 = conv_layer_fn(l1, "gemm")
    conv2 = conv_layer_fn(l2, method)
    conv3 = conv_layer_fn(l3, method)

    def head(y, fc_w, fc_b):
        y = y.mean(axis=(2, 3))  # global average pool -> (N, 64)
        return y @ fc_w + fc_b

    if method == "gemm":

        def fn_gemm(x, w1, w2, w3, fc_w, fc_b):
            y = jax.nn.relu(conv1(x, w1))
            y = _maxpool2x2(y)  # 32 -> 16
            y = jax.nn.relu(conv2(y, w2))
            y = _maxpool2x2(y)  # 16 -> 8
            y = jax.nn.relu(conv3(y, w3))
            return head(y, fc_w, fc_b)

        return fn_gemm

    def fn_sparse(x, w1, v2, i2, v3, i3, fc_w, fc_b):
        y = jax.nn.relu(conv1(x, w1))
        y = _maxpool2x2(y)  # 32 -> 16
        y = jax.nn.relu(conv2(y, v2, i2))
        y = _maxpool2x2(y)  # 16 -> 8
        y = jax.nn.relu(conv3(y, v3, i3))
        return head(y, fc_w, fc_b)

    return fn_sparse


def minicnn_feature_dim() -> int:
    return MINICNN_LAYERS[-1].m


def minicnn_num_classes() -> int:
    return MINICNN_CLASSES
