"""``pad_in`` — Escoin's input-padding kernel (paper §3.1, Fig 9).

The paper pads the ifmap once so the sconv inner loop needs no bounds
checks. On TPU the analogue is a trivial grid-over-(N, C) kernel whose
block writes the interior of a zero-initialised padded plane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_kernel(x_ref, o_ref, *, pad: int, h: int, w: int):
    # x_ref: (1, 1, H, W); o_ref: (1, 1, Hp, Wp)
    o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[0, 0, pad : pad + h, pad : pad + w] = x_ref[0, 0]


def pad_input(x: jax.Array, pad: int) -> jax.Array:
    """Zero-pad ``x`` (N, C, H, W) spatially by ``pad`` on each side."""
    if pad == 0:
        return x
    n, c, h, w = x.shape
    hp, wp = h + 2 * pad, w + 2 * pad
    kernel = functools.partial(_pad_kernel, pad=pad, h=h, w=w)
    return pl.pallas_call(
        kernel,
        grid=(n, c),
        in_specs=[pl.BlockSpec((1, 1, h, w), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, hp, wp), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, hp, wp), x.dtype),
        interpret=True,
    )(x)
