"""L1: Pallas kernels for the paper's compute hot-spots.

Every kernel is lowered with ``interpret=True`` (the CPU PJRT client
cannot execute Mosaic custom-calls); correctness is pinned against the
pure-jnp oracles in :mod:`ref` by ``python/tests/``.

Kernels:

* :func:`pad.pad_input`      — ``pad_in`` (paper Fig 9's padding kernel).
* :func:`im2col.im2col`      — the lowering transform (baseline path).
* :func:`gemm.matmul`        — tiled dense matmul (cuBLAS ``sgemm`` proxy).
* :func:`spmm.ell_spmm`      — sparse x dense matmul (cuSPARSE ``csrmm`` proxy).
* :func:`sconv.sconv`        — **Escoin's direct sparse convolution**.
"""

from . import gemm, im2col, pad, ref, sconv, spmm  # noqa: F401
