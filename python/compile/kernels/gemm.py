"""Tiled dense matmul — the cuBLAS ``sgemm`` proxy for the lowering
baseline. Computes ``C[n] = A @ B[n]`` with A the (M, K) dense filter
matrix (zeros included after pruning, exactly the paper's CUBLAS
configuration) and B the (N, K, L) lowered input.

Grid = (N, M/bm): each step contracts a (bm, K) stripe of A against the
whole (K, L) image — K and L stay resident, matching the MXU-friendly
"stationary weight stripe" tiling. Block sizes adapt to M so no shape
padding is required.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_bm(m: int) -> int:
    for bm in (32, 16, 8, 4, 2, 1):
        if m % bm == 0:
            return bm
    return 1


def _matmul_kernel(a_ref, b_ref, o_ref):
    # a_ref: (bm, K); b_ref: (1, K, L); o_ref: (1, bm, L)
    o_ref[0] = jnp.dot(a_ref[...], b_ref[0], preferred_element_type=jnp.float32)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """``C[n] = A @ B[n]``: a (M, K), b (N, K, L) -> (N, M, L)."""
    m, k = a.shape
    n, kb, l = b.shape
    assert k == kb, f"contraction mismatch {k} vs {kb}"
    bm = _pick_bm(m)
    return pl.pallas_call(
        functools.partial(_matmul_kernel),
        grid=(n, m // bm),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, k, l), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, l), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m, l), jnp.float32),
        interpret=True,
    )(a, b)
