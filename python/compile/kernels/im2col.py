"""The lowering kernel (paper §2.2, Fig 2): materialise the im2col matrix.

This is the baseline path's data transformation — the memory-bandwidth
overhead Escoin eliminates. Grid = (N, C*R*S): each step extracts one
lowered row (the strided window of one filter tap) from the padded image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import ConvShape


def _im2col_kernel(x_ref, o_ref, *, shape: ConvShape):
    # x_ref: (1, C*Hp, Wp); o_ref: (1, 1, E*F)
    # grid: (n, row) with row = (c, r, s) flattened.
    e, f = shape.out_h, shape.out_w
    stride = shape.stride
    row_id = pl.program_id(1)
    rs = shape.r * shape.s
    c = row_id // rs
    r = (row_id // shape.s) % shape.r
    s = row_id % shape.s
    span_h = (e - 1) * stride + 1
    span_w = (f - 1) * stride + 1
    window = pl.load(
        x_ref,
        (0, pl.dslice(c * shape.padded_h + r, span_h), pl.dslice(s, span_w)),
    )
    if stride != 1:
        window = window[::stride, ::stride]
    o_ref[0, 0] = window.reshape(e * f)


def im2col(x_padded: jax.Array, shape: ConvShape) -> jax.Array:
    """Lower ``x_padded`` (N, C, Hp, Wp) to (N, C*R*S, E*F)."""
    n, c, hp, wp = x_padded.shape
    assert (hp, wp) == (shape.padded_h, shape.padded_w), "input not padded"
    x2d = x_padded.reshape(n, c * hp, wp)
    crs = shape.crs
    ef = shape.ef
    kernel = functools.partial(_im2col_kernel, shape=shape)
    return pl.pallas_call(
        kernel,
        grid=(n, crs),
        in_specs=[pl.BlockSpec((1, c * hp, wp), lambda i, j: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, ef), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, crs, ef), jnp.float32),
        interpret=True,
    )(x2d)
