"""Escoin's direct sparse convolution as a Pallas kernel (paper §3).

TPU re-think of the paper's CUDA mapping (DESIGN.md §6):

* The sparse filter bank arrives **weight-stretched** (paper §3.1) and
  **ELL-padded**: ``values``/``colidx`` are (M, K) with K static, padding
  slots hold value 0 / offset 0. ``colidx[m, k]`` is a flat offset into
  the padded per-image input viewed as ``(C*Hp, Wp)``.
* Grid = (N, M): each grid step owns one output plane (E, F) — the
  thread-block-per-output-channel partitioning of §3.3, with the VMEM
  accumulator playing the role of register-resident partial sums.
* Per nonzero, a ``pl.load`` with dynamic start pulls an input window
  whose rows are contiguous — the coalescing analogue of Fig 6 — and the
  fori_loop over K slots is the static-trip-count version of the CSR row
  walk in Algorithm 2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import ConvShape


def _sconv_kernel(x_ref, val_ref, idx_ref, o_ref, *, shape: ConvShape, k: int):
    # x_ref:   (1, C*Hp, Wp)  one padded image, channel-rows flattened
    # val_ref: (1, K) f32     one stretched+ELL filter row
    # idx_ref: (1, K) i32     flat offsets (c*Hp + r)*Wp + s, stretched
    # o_ref:   (1, 1, E, F)
    e, f = shape.out_h, shape.out_w
    wp = shape.padded_w
    stride = shape.stride
    span_h = (e - 1) * stride + 1
    span_w = (f - 1) * stride + 1

    def body(slot, acc):
        off = idx_ref[0, slot]
        row = off // wp
        col = off % wp
        window = pl.load(
            x_ref,
            (0, pl.dslice(row, span_h), pl.dslice(col, span_w)),
        )
        if stride != 1:
            window = window[::stride, ::stride]
        return acc + val_ref[0, slot] * window

    acc = jax.lax.fori_loop(0, k, body, jnp.zeros((e, f), jnp.float32))
    o_ref[0, 0] = acc


def sconv(
    x_padded: jax.Array,
    values: jax.Array,
    colidx: jax.Array,
    shape: ConvShape,
) -> jax.Array:
    """Direct sparse convolution.

    ``x_padded``: (N, C, Hp, Wp) — already padded (see :mod:`pad`).
    ``values``/``colidx``: (M, K) ELL arrays with *stretched* offsets.
    Returns (N, M, E, F).
    """
    n, c, hp, wp = x_padded.shape
    assert (hp, wp) == (shape.padded_h, shape.padded_w), "input not padded"
    m, k = values.shape
    assert m == shape.m and colidx.shape == (m, k)
    x2d = x_padded.reshape(n, c * hp, wp)
    e, f = shape.out_h, shape.out_w
    kernel = functools.partial(_sconv_kernel, shape=shape, k=k)
    return pl.pallas_call(
        kernel,
        grid=(n, m),
        in_specs=[
            pl.BlockSpec((1, c * hp, wp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, e, f), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m, e, f), jnp.float32),
        interpret=True,
    )(x2d, values, colidx)
