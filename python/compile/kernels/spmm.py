"""ELL sparse x dense matmul — the cuSPARSE ``csrmm`` proxy.

``C[n] = W_sparse @ B[n]`` where W is the pruned (M, CRS) filter matrix in
ELL form (canonical, *unstretched* column ids into the lowered matrix's
rows) and B is the (N, CRS, L) im2col output.

Grid = (N, M): one output row per step. Every slot gathers one row of B
by dynamic index — the irregular indirection that makes csrmm cache-
hostile (the Fig 10 experiment); we reproduce the access pattern
faithfully rather than hiding it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(val_ref, idx_ref, b_ref, o_ref, *, k: int, l: int):
    # val_ref/idx_ref: (1, K); b_ref: (1, CRS, L); o_ref: (1, 1, L)
    def body(slot, acc):
        col = idx_ref[0, slot]
        brow = pl.load(b_ref, (0, pl.dslice(col, 1), pl.dslice(0, l)))
        return acc + val_ref[0, slot] * brow[0]

    acc = jax.lax.fori_loop(0, k, body, jnp.zeros((l,), jnp.float32))
    o_ref[0, 0] = acc


def ell_spmm(values: jax.Array, colidx: jax.Array, b: jax.Array) -> jax.Array:
    """values/colidx (M, K) ELL; b (N, CRS, L). Returns (N, M, L)."""
    m, k = values.shape
    n, crs, l = b.shape
    assert colidx.shape == (m, k)
    kernel = functools.partial(_spmm_kernel, k=k, l=l)
    return pl.pallas_call(
        kernel,
        grid=(n, m),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, crs, l), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m, l), jnp.float32),
        interpret=True,
    )(values, colidx, b)
