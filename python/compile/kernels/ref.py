"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

These are deliberately written with stock XLA ops (``lax.conv``,
``jnp.take``, ``jnp.einsum``) so that a bug in one of our Pallas kernels
cannot be mirrored in its oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pad_ref(x: jax.Array, pad: int) -> jax.Array:
    """Zero-pad NCHW input spatially by ``pad`` on each side."""
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))


def conv_ref(x: jax.Array, w: jax.Array, stride: int, pad: int) -> jax.Array:
    """Dense NCHW convolution via ``lax.conv`` — the layer-level oracle.

    ``x``: (N, C, H, W); ``w``: (M, C, R, S). Returns (N, M, E, F).
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def im2col_ref(xp: jax.Array, r: int, s: int, stride: int, e: int, f: int) -> jax.Array:
    """Lowered matrix from a padded input (paper Fig 2).

    ``xp``: (N, C, Hp, Wp) already padded. Returns (N, C*R*S, E*F) where
    row (c, rr, ss), column (h, w) holds ``xp[n, c, h*stride+rr, w*stride+ss]``.
    """
    n, c, _hp, _wp = xp.shape
    cols = []
    for rr in range(r):
        for ss in range(s):
            window = jax.lax.slice(
                xp,
                (0, 0, rr, ss),
                (n, c, rr + (e - 1) * stride + 1, ss + (f - 1) * stride + 1),
                (1, 1, stride, stride),
            )  # (N, C, E, F)
            cols.append(window.reshape(n, c, e * f))
    # taps within channel: (N, C, R*S, E*F) then flatten to (N, C*R*S, E*F).
    stacked = jnp.stack(cols, axis=2)
    return stacked.reshape(n, c * r * s, e * f)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched ``C[n] = A @ B[n]``: a (M, K), b (N, K, L) -> (N, M, L)."""
    return jnp.einsum("mk,nkl->nml", a, b)


def ell_spmm_ref(values: jax.Array, colidx: jax.Array, b: jax.Array) -> jax.Array:
    """ELL sparse x dense: values/colidx (M, K), b (N, Kc, L) -> (N, M, L).

    Padding slots carry value 0, so gathering row 0 for them is inert.
    """
    gathered = jnp.take(b, colidx, axis=1)  # (N, M, K, L)
    return jnp.einsum("mk,nmkl->nml", values, gathered)


def sconv_ref(x: jax.Array, dense_w: np.ndarray, shape) -> jax.Array:
    """Oracle for the direct sparse conv: a dense conv with the pruned
    weights (sparsity is an implementation detail, not semantics)."""
    w = jnp.asarray(dense_w.reshape(shape.m, shape.c, shape.r, shape.s))
    return conv_ref(x, w, shape.stride, shape.pad)
