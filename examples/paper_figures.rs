//! Regenerate every paper table/figure in one run.
//!
//! ```text
//! cargo run --release --example paper_figures -- [--table2] [--table3]
//!     [--fig8] [--fig9] [--fig10] [--fig11] [--quick]
//! ```
//!
//! With no flags, everything runs. `--quick` shrinks batch/spatial scale
//! so the full sweep finishes in a couple of minutes on a laptop.

use escoin::bench_harness::fig10::{fig10_cache_rates, Fig10Opts};
use escoin::bench_harness::fig11::{fig11_overall, geomean_overall};
use escoin::bench_harness::fig8::{fig8_sparse_conv, geomean_speedups, Fig8Opts};
use escoin::bench_harness::fig9::fig9_breakdown;
use escoin::bench_harness::{table2_platforms, table3_rows, BenchOpts, Table};
use escoin::config::all_networks;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let has = |f: &str| args.iter().any(|a| a == f);
    let all = !args.iter().any(|a| a.starts_with("--fig") || a.starts_with("--table"));
    let quick = has("--quick");

    let opts = Fig8Opts {
        batch: if quick { 1 } else { 2 },
        spatial_scale: if quick { 2 } else { 1 },
        threads: escoin::util::default_threads(),
        bench: if quick {
            BenchOpts { warmup: 0, iters: 1 }
        } else {
            BenchOpts::from_env()
        },
    };

    if all || has("--table2") {
        print!("{}", table2_platforms().render());
        println!();
    }
    if all || has("--table3") {
        print!("{}", table3_rows().render());
        println!();
    }
    if all || has("--fig8") {
        let mut t = Table::new(
            "Fig 8: sparse CONV speedup over CUBLAS",
            &["model", "CUSPARSE x", "Escoin x"],
        );
        let mut rows = Vec::new();
        for net in all_networks() {
            let row = fig8_sparse_conv(&net, opts);
            t.row(vec![
                row.model.clone(),
                format!("{:.2}x", row.speedup_cusparse()),
                format!("{:.2}x", row.speedup_escoin()),
            ]);
            rows.push(row);
        }
        let (cb, cs) = geomean_speedups(&rows);
        print!("{}", t.render());
        println!("geomean: {cb:.2}x over CUBLAS (paper 2.63x), {cs:.2}x over CUSPARSE (paper 3.07x)\n");
    }
    if all || has("--fig9") {
        let mut t = Table::new(
            "Fig 9: execution-time breakdown (fractions)",
            &["model", "approach", "im2col", "sgemm", "csrmm", "sconv", "pad_in"],
        );
        for net in all_networks() {
            for row in fig9_breakdown(&net, opts) {
                t.row(vec![
                    row.model.clone(),
                    row.approach.to_string(),
                    format!("{:.0}%", 100.0 * row.fraction("im2col")),
                    format!("{:.0}%", 100.0 * row.fraction("sgemm")),
                    format!("{:.0}%", 100.0 * row.fraction("csrmm")),
                    format!("{:.0}%", 100.0 * row.fraction("sconv")),
                    format!("{:.0}%", 100.0 * row.fraction("pad_in")),
                ]);
            }
        }
        print!("{}", t.render());
        println!();
    }
    if all || has("--fig10") {
        let fopts = Fig10Opts {
            spatial_scale: if quick { 2 } else { 1 },
            max_layers: if quick { 3 } else { 0 },
        };
        let mut t = Table::new(
            "Fig 10: simulated cache hit rates",
            &["model", "csrmm RO", "sconv RO", "csrmm L2", "sconv L2"],
        );
        for net in all_networks() {
            let row = fig10_cache_rates(&net, fopts);
            t.row(vec![
                row.model.clone(),
                format!("{:.0}%", 100.0 * row.csrmm_ro),
                format!("{:.0}%", 100.0 * row.sconv_ro),
                format!("{:.0}%", 100.0 * row.csrmm_l2),
                format!("{:.0}%", 100.0 * row.sconv_l2),
            ]);
        }
        print!("{}", t.render());
        println!("(paper: sconv RO 71-81%, csrmm RO 52-57%)\n");
    }
    if all || has("--fig11") {
        let mut t = Table::new(
            "Fig 11: overall inference speedup over CUBLAS",
            &["model", "CUSPARSE x", "Escoin x", "sparse-conv share"],
        );
        let mut rows = Vec::new();
        for net in all_networks() {
            let row = fig11_overall(&net, opts);
            t.row(vec![
                row.model.clone(),
                format!("{:.2}x", row.speedup_cusparse()),
                format!("{:.2}x", row.speedup_escoin()),
                format!("{:.0}%", 100.0 * row.sparse_conv_fraction),
            ]);
            rows.push(row);
        }
        let (cb, cs) = geomean_overall(&rows);
        print!("{}", t.render());
        println!("geomean: {cb:.2}x over CUBLAS (paper 1.38x), {cs:.2}x over CUSPARSE (paper 1.60x)");
    }
}
