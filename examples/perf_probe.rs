//! Perf baseline probe: plan-based execution vs the seed free-function
//! path on representative layer shapes, emitted as machine-readable
//! `BENCH_sconv.json` (per-shape ns/iter) so future PRs can diff against
//! a recorded baseline.
//!
//! Four row families:
//! * `gemm`/`spmm`/`sconv` — compiled plan on a **shared pool** vs the
//!   seed free functions (which re-pad, allocate, and spawn an
//!   ephemeral pool per call).
//! * `sconv-pool` — the worker-pool headline: per-call thread spawning
//!   (`free_ns`) vs the persistent shared pool (`plan_ns`) at batch 1
//!   (the serving path that motivated the pool) and batch 8.
//! * `serve-pipeline-b1`/`b8` — end-to-end serving ns/request on a
//!   paced request stream: sequential executor (`free_ns`,
//!   `pipeline_depth = 1`) vs the two-slot pipeline (`plan_ns`,
//!   `pipeline_depth = 2`) that overlaps batch N+1's head layers and
//!   batch formation with batch N's tail layers.
//! * `serve-load-b1`/`b8` — the closed-loop Poisson load harness
//!   against the two-tenant (minicnn + microcnn) front door with
//!   admission control and a 250 ms deadline. Extended rows: beyond
//!   the base keys (`free_ns`/`plan_ns` mirror p50/p99 ns) they carry
//!   `p50_ns`, `p99_ns`, `throughput_rps_milli`, `rejected`, and
//!   `deadline_hit_milli`. Request count via `ESCOIN_LOADGEN_REQUESTS`
//!   (default 64).
//! * `serve-chaos-b1`/`b8` — the same closed-loop harness against a
//!   single-tenant minicnn server with a seeded chaos plan (tile
//!   panics, NaN poisons, a straggler) layered over it. Extended rows:
//!   `p50_ns`, `p99_ns`, `failed`, `shed`, `recovery_ns`,
//!   `deadline_hit_milli`. With `--features fault-inject` the faults
//!   are armed and the supervised executor degrades gracefully;
//!   without it the identical row is a clean run (`failed == 0`), so
//!   the rows exist — and the schema holds — on every build.
//! * `replan-full-vs-incremental` — ns per server replan: rebuilding
//!   every layer from scratch (`free_ns`, weights regenerated +
//!   re-transformed, what `build_plan` used to do) vs an incremental
//!   replan through the shared `PlanCache` (`plan_ns`, only the flipped
//!   layer compiles).
//! * `googlenet-dag-b1`/`b8` — whole-network GoogLeNet iteration:
//!   sequential topological walk (`free_ns`) vs the asynchronous DAG
//!   walk (`plan_ns`) that overlaps each inception module's four
//!   branch chains as dependency-chained pool jobs. Batch 1 is the
//!   latency case branch overlap targets (per-layer tile counts are
//!   smallest there); these rows are the heaviest in the probe —
//!   trim `ESCOIN_BENCH_ITERS` when iterating.
//! * `sconv-blocked-b1`/`b8` — the cache-blocked multi-channel
//!   microkernel (`plan_ns`, register blocks of `mr` output channels
//!   over L1-sized row blocks, input loaded once per block and reused
//!   `mr`x) vs the unblocked per-channel kernel (`free_ns`,
//!   `TilePolicy::unblocked()`) on the large-input AlexNet conv2
//!   class — the layer whose input group falls out of cache between
//!   channels without blocking.
//! * `sconv-simd-b1`/`b8` — the `SIMD_LANES`-wide vectorized
//!   microkernel (`plan_ns`, `TilePolicy::lanes = SIMD_LANES`: each
//!   nonzero broadcast across a lane strip of contiguous output
//!   pixels, `mr x LANES` MACs per resident input block) vs the scalar
//!   blocked kernel (`free_ns`, `lanes = 1`), same shape as the
//!   blocked rows. Policies name their lane width explicitly, so
//!   these rows appear with or without `--features simd`.
//! * `sconv-balanced-b1` — the vectorized kernel over the
//!   bank-balanced sliced-ELL layout (`plan_ns`,
//!   `SparseLayout::Balanced`: rows of each `mr`-channel bank padded
//!   to equal slot counts — one static trip count per register block)
//!   vs the same vector kernel walking raw CSR rows (`free_ns`).
//! * `sconv-strided-b1`/`b8` — the strided row-gather register-block
//!   kernel (`plan_ns`, per-phase gather strips shared by all `mr`
//!   channels of the block and reused across nonzeros via the epoch
//!   memo) vs the per-channel strided gather (`free_ns`,
//!   `TilePolicy::unblocked()`: every channel re-gathers every output
//!   row) on a ResNet-class stride-2 3x3 layer. Under `--features
//!   simd` the blocked side is additionally lane-vectorized.
//! * `sconv-depthwise-b1` — the same comparison on a MobileNet-class
//!   depthwise layer (`groups == C`): the group-aware channel packer
//!   coalesces whole single-channel groups into register blocks
//!   (`plan_ns`) vs one gather pass per channel (`free_ns`).
//! * `resnet50-dag-b1` — whole-network ResNet-50 iteration at batch 1:
//!   sequential topological walk (`free_ns`) vs the asynchronous DAG
//!   walk (`plan_ns`) over the residual branch/`Add`-merge graph.
//! * `mobilenet-b1` — whole-network MobileNetV1 iteration at batch 1:
//!   every conv planned with `TilePolicy::unblocked()` (`free_ns`,
//!   per-channel gather) vs the default blocked policy (`plan_ns`),
//!   same weight stream — the end-to-end win of the grouped/strided
//!   blocked kernels on a depthwise-separable network.
//! * `retile-adaptive` — a deliberately coarse tiling (`free_ns`,
//!   one channel tile per image at batch `threads + 1`, so a lane must
//!   run two whole-image tiles — straggler-bound by construction) vs
//!   the tiling the telemetry feedback loop (`TilePolicy::adjusted`,
//!   driven by measured per-job imbalance) refines it into
//!   (`plan_ns`).
//! * `sconv-autotune-b1` — per kernel class (blocked 27x27 / 13x13 /
//!   strided 28x28 shapes): the default `TilePolicy` (`free_ns`) vs
//!   the simulator-ranked winner baked by the offline sweep
//!   (`plan_ns`), measured ns/iter at batch 1.
//! * `autotune-predicted-vs-measured` — the prediction behind those
//!   rows, on the same shapes: simulated bytes-from-DRAM of the
//!   default policy (`free_ns`) vs the tuned winner (`plan_ns`).
//!   Values are bytes, not ns — the row pairs the sim ranking with the
//!   measured `sconv-autotune-b1` rows so the predicted-vs-measured
//!   contract stays diffable across PRs.
//!
//! ```text
//! cargo run --release --example perf_probe [--out PATH]
//! ```
//!
//! Knobs: `ESCOIN_THREADS`, `ESCOIN_BENCH_WARMUP`, `ESCOIN_BENCH_ITERS`,
//! `ESCOIN_LOADGEN_REQUESTS`.

use escoin::bench_harness::{
    bench_median, run_chaos, run_load, BenchOpts, ChaosConfig, LoadGenConfig,
};
use escoin::config::{alexnet, googlenet, mobilenetv1, resnet50, ConvShape, LayerKind};
use escoin::conv::{
    lowered_gemm_parallel, lowered_spmm_parallel, sconv_parallel, ConvWeights, LayerPlan, Method,
    NetworkPlan, PlanCache, SparseLayout, TilePolicy, WeightedOp, Workspace, WorkspaceArena,
    SIMD_LANES,
};
use escoin::coordinator::{BatcherConfig, RouterConfig, ServerConfig, ServerHandle};
use escoin::simulator::{autotune_policy, P100_GEOMETRY};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{default_threads, Rng, WorkerPool};
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Row {
    shape: &'static str,
    method: &'static str,
    batch: usize,
    free_ns: u128,
    plan_ns: u128,
}

/// A `serve-load-*` row: the base five keys (so existing diff tooling
/// keeps working; `free_ns`/`plan_ns` mirror p50/p99) plus the SLO
/// fields the load harness reports. Serialized with the extended key
/// set the CI schema check expects for `serve-load` methods.
struct LoadRow {
    shape: &'static str,
    method: &'static str,
    batch: usize,
    free_ns: u128,
    plan_ns: u128,
    p50_ns: u128,
    p99_ns: u128,
    throughput_rps_milli: u128,
    rejected: u128,
    deadline_hit_milli: u128,
}

/// A `serve-chaos-*` row: the base five keys (`free_ns`/`plan_ns`
/// mirror p50/p99 again) plus the fault accounting of a chaos load run
/// — failed/shed request counts, the wall-clock recovery gap after the
/// first fault, and the deadline-hit rate under faults. Emitted on
/// every build: without `--features fault-inject` the chaos plan is
/// inert, so the row degrades to a clean load run with `failed == 0`.
struct ChaosRow {
    shape: &'static str,
    method: &'static str,
    batch: usize,
    free_ns: u128,
    plan_ns: u128,
    p50_ns: u128,
    p99_ns: u128,
    failed: u128,
    shed: u128,
    recovery_ns: u128,
    deadline_hit_milli: u128,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_sconv.json".to_string());
    let threads = default_threads();
    let pool = WorkerPool::new(threads);
    let bench = BenchOpts::from_env();
    let batch = 2usize;

    let shapes: [(&'static str, ConvShape); 3] = [
        (
            "alexnet_conv2_5x5_27x27_sp85",
            ConvShape::new(96, 256, 27, 27, 5, 5, 1, 2)
                .with_groups(2)
                .with_sparsity(0.85),
        ),
        (
            "alexnet_conv3_3x3_13x13_sp88",
            ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88),
        ),
        (
            "alexnet_conv3_scaled_3x3_6x6",
            ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1)
                .with_sparsity(0.88)
                .scaled_spatial(2),
        ),
    ];

    let mut rows: Vec<Row> = Vec::new();
    let mut ws = Workspace::new();
    for (name, shape) in &shapes {
        let mut rng = Rng::new(1);
        let x = Tensor4::random_activations(Dims4::new(batch, shape.c, shape.h, shape.w), &mut rng);
        let w = ConvWeights::synthetic(shape, &mut rng);
        let csr = w.csr_banks();
        let st = w.stretched_banks();

        for (method, label) in [
            (Method::LoweredGemm, "gemm"),
            (Method::LoweredSpmm, "spmm"),
            (Method::DirectSparse, "sconv"),
        ] {
            // Seed free-function path: re-pads, allocates, and spawns
            // an ephemeral pool per call.
            let free = bench_median(bench, || match method {
                Method::LoweredGemm => lowered_gemm_parallel(shape, &x, &w, threads),
                Method::LoweredSpmm => lowered_spmm_parallel(shape, &x, &csr, threads),
                _ => sconv_parallel(shape, &x, &st, threads),
            });
            // Plan path: operands compiled once, workspace + output
            // reused, persistent shared pool.
            let plan = LayerPlan::build(shape, &w, method);
            ws.ensure(plan.workspace_floats(batch, pool.workers()));
            let mut out = Tensor4::zeros(plan.out_dims(batch));
            let planned = bench_median(bench, || {
                plan.execute_into(batch, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: label,
                batch,
                free_ns: free.as_nanos(),
                plan_ns: planned.as_nanos(),
            });
            println!(
                "{name:<32} {label:<10} free {free:?}  plan {planned:?}  ({:.2}x)",
                free.as_secs_f64() / planned.as_secs_f64().max(1e-12)
            );
        }
    }

    // Pool-vs-spawn headline: identical compiled plan, executed once per
    // call on a fresh pool (per-call thread spawn, what the seed kernels
    // did) vs on the persistent shared pool — batch 1 (serving) and 8.
    {
        let (name, shape) = &shapes[1];
        let mut rng = Rng::new(2);
        let w = ConvWeights::synthetic(shape, &mut rng);
        let plan = LayerPlan::build(shape, &w, Method::DirectSparse);
        for (b, label) in [(1usize, "b1"), (8usize, "b8")] {
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(plan.workspace_floats(b, pool.workers()));
            let mut out = Tensor4::zeros(plan.out_dims(b));
            let spawn = bench_median(bench, || {
                let fresh = WorkerPool::new(threads);
                plan.execute_into(b, x.data(), &fresh, &mut ws, out.data_mut(), None)
            });
            let pooled = bench_median(bench, || {
                plan.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: if label == "b1" {
                    "sconv-pool-b1"
                } else {
                    "sconv-pool-b8"
                },
                batch: b,
                free_ns: spawn.as_nanos(),
                plan_ns: pooled.as_nanos(),
            });
            println!(
                "pool-vs-spawn batch {b}: spawn-per-call {spawn:?}  pool {pooled:?}  ({:.2}x)",
                spawn.as_secs_f64() / pooled.as_secs_f64().max(1e-12)
            );
        }
    }

    // Blocked-microkernel headline: the cache-blocked multi-channel
    // kernel vs the unblocked per-channel kernel (byte-identical
    // outputs — the policies only change how the work is cut), on the
    // large-input conv2 class where the input group falls out of cache
    // between channels without blocking. Batch 1 (serving) and 8.
    {
        let (name, shape) = &shapes[0];
        let mut rng = Rng::new(3);
        let w = ConvWeights::synthetic(shape, &mut rng);
        let unblocked = LayerPlan::build_with_policy(
            shape,
            &w,
            Method::DirectSparse,
            TilePolicy::unblocked(),
        );
        // Pinned to the scalar blocked kernel (the simd feature flips the
        // *default* lanes): these rows compare how the same float ops are
        // cut, so they must stay byte-identical and lane-free either way.
        let blocked = LayerPlan::build_with_policy(
            shape,
            &w,
            Method::DirectSparse,
            TilePolicy {
                lanes: 1,
                layout: SparseLayout::Csr,
                ..TilePolicy::default()
            },
        );
        for (b, label) in [(1usize, "sconv-blocked-b1"), (8usize, "sconv-blocked-b8")] {
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(
                unblocked
                    .workspace_floats(b, pool.workers())
                    .max(blocked.workspace_floats(b, pool.workers())),
            );
            let mut out = Tensor4::zeros(blocked.out_dims(b));
            let per_channel = bench_median(bench, || {
                unblocked.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            let multi_channel = bench_median(bench, || {
                blocked.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: label,
                batch: b,
                free_ns: per_channel.as_nanos(),
                plan_ns: multi_channel.as_nanos(),
            });
            println!(
                "{label}: per-channel {per_channel:?}  blocked {multi_channel:?}  ({:.2}x)",
                per_channel.as_secs_f64() / multi_channel.as_secs_f64().max(1e-12)
            );
        }
    }

    // Vectorized-microkernel headline: the lane-strip kernel vs the
    // scalar blocked kernel (ULP-equivalent outputs — the lane order
    // reassociates the scalar 4-wide grouping), and the bank-balanced
    // layout vs raw CSR under the same vector kernel (byte-identical
    // outputs — padding slots are arithmetic no-ops). Same conv2-class
    // shape as the blocked rows; explicit lane counts so the rows emit
    // identically with and without `--features simd`.
    {
        let (name, shape) = &shapes[0];
        let mut rng = Rng::new(5);
        let w = ConvWeights::synthetic(shape, &mut rng);
        let scalar_policy = TilePolicy {
            lanes: 1,
            layout: SparseLayout::Csr,
            ..TilePolicy::default()
        };
        let simd_policy = TilePolicy {
            lanes: SIMD_LANES,
            layout: SparseLayout::Csr,
            ..TilePolicy::default()
        };
        let balanced_policy = TilePolicy {
            lanes: SIMD_LANES,
            layout: SparseLayout::Balanced,
            ..TilePolicy::default()
        };
        let scalar = LayerPlan::build_with_policy(shape, &w, Method::DirectSparse, scalar_policy);
        let simd = LayerPlan::build_with_policy(shape, &w, Method::DirectSparse, simd_policy);
        let balanced =
            LayerPlan::build_with_policy(shape, &w, Method::DirectSparse, balanced_policy);
        for (b, label) in [(1usize, "sconv-simd-b1"), (8usize, "sconv-simd-b8")] {
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(
                scalar
                    .workspace_floats(b, pool.workers())
                    .max(simd.workspace_floats(b, pool.workers())),
            );
            let mut out = Tensor4::zeros(simd.out_dims(b));
            let scalar_t = bench_median(bench, || {
                scalar.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            let simd_t = bench_median(bench, || {
                simd.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: label,
                batch: b,
                free_ns: scalar_t.as_nanos(),
                plan_ns: simd_t.as_nanos(),
            });
            println!(
                "{label}: scalar {scalar_t:?}  simd({SIMD_LANES} lanes) {simd_t:?}  ({:.2}x)",
                scalar_t.as_secs_f64() / simd_t.as_secs_f64().max(1e-12)
            );
        }
        {
            let b = 1usize;
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(
                simd.workspace_floats(b, pool.workers())
                    .max(balanced.workspace_floats(b, pool.workers())),
            );
            let mut out = Tensor4::zeros(balanced.out_dims(b));
            let csr_t = bench_median(bench, || {
                simd.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            let bal_t = bench_median(bench, || {
                balanced.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: "sconv-balanced-b1",
                batch: b,
                free_ns: csr_t.as_nanos(),
                plan_ns: bal_t.as_nanos(),
            });
            println!(
                "sconv-balanced-b1: simd-csr {csr_t:?}  simd-balanced {bal_t:?}  ({:.2}x)",
                csr_t.as_secs_f64() / bal_t.as_secs_f64().max(1e-12)
            );
        }
    }

    // Strided row-gather headline: the register-blocked strided kernel
    // (per-phase gather strips shared by all `mr` channels of the block
    // and memoized across nonzeros) vs the per-channel strided gather
    // (`TilePolicy::unblocked()`: every channel re-gathers every output
    // row from scratch), on a ResNet-class stride-2 3x3 layer. The
    // default policy follows the build's lane width, so the simd leg
    // additionally vectorizes the blocked side.
    {
        let shape = ConvShape::new(64, 64, 56, 56, 3, 3, 2, 1).with_sparsity(0.7);
        let mut rng = Rng::new(6);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let gather =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, TilePolicy::unblocked());
        let blocked =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, TilePolicy::default());
        for (b, label) in [(1usize, "sconv-strided-b1"), (8usize, "sconv-strided-b8")] {
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(
                gather
                    .workspace_floats(b, pool.workers())
                    .max(blocked.workspace_floats(b, pool.workers())),
            );
            let mut out = Tensor4::zeros(blocked.out_dims(b));
            let gather_t = bench_median(bench, || {
                gather.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            let blocked_t = bench_median(bench, || {
                blocked.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: "resnet_conv_3x3_s2_56x56_sp70",
                method: label,
                batch: b,
                free_ns: gather_t.as_nanos(),
                plan_ns: blocked_t.as_nanos(),
            });
            println!(
                "{label}: per-channel-gather {gather_t:?}  blocked {blocked_t:?}  ({:.2}x)",
                gather_t.as_secs_f64() / blocked_t.as_secs_f64().max(1e-12)
            );
        }
    }

    // Depthwise headline: the same gather-vs-blocked comparison on a
    // MobileNet-class depthwise layer (`groups == C`), where the
    // group-aware packer coalesces whole single-channel groups into
    // `mr`-channel register blocks instead of falling back to one
    // per-channel pass per group.
    {
        let shape = ConvShape::new(512, 512, 14, 14, 3, 3, 1, 1)
            .with_groups(512)
            .with_sparsity(0.5);
        let mut rng = Rng::new(7);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let gather =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, TilePolicy::unblocked());
        let blocked =
            LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, TilePolicy::default());
        let b = 1usize;
        let x = Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
        ws.ensure(
            gather
                .workspace_floats(b, pool.workers())
                .max(blocked.workspace_floats(b, pool.workers())),
        );
        let mut out = Tensor4::zeros(blocked.out_dims(b));
        let gather_t = bench_median(bench, || {
            gather.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
        });
        let blocked_t = bench_median(bench, || {
            blocked.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
        });
        rows.push(Row {
            shape: "mobilenet_dw_3x3_14x14_g512_sp50",
            method: "sconv-depthwise-b1",
            batch: b,
            free_ns: gather_t.as_nanos(),
            plan_ns: blocked_t.as_nanos(),
        });
        println!(
            "sconv-depthwise-b1: per-channel {gather_t:?}  blocked {blocked_t:?}  ({:.2}x)",
            gather_t.as_secs_f64() / blocked_t.as_secs_f64().max(1e-12)
        );
    }

    // Adaptive-retile headline: a deliberately coarse tiling vs the
    // tiling the measured-imbalance feedback loop refines it into —
    // the serving executor runs exactly this adjustment at its replan
    // checkpoints. The coarse start is ONE channel tile per image
    // (`target_tiles = 1`, per-image parallelism only) at a batch of
    // `threads + 1`, so some lane must run two whole-image tiles while
    // the rest idle — a measured per-job imbalance of at least
    // 2 / ((threads+1)/threads), comfortably above the refine
    // threshold, guaranteeing the loop fires.
    {
        let shape = ConvShape::new(16, 64, 64, 64, 3, 3, 1, 1).with_sparsity(0.9);
        let mut rng = Rng::new(4);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let b = threads + 1;
        let x = Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
        let coarse_policy = TilePolicy {
            target_tiles: 1,
            ..TilePolicy::default()
        };
        let coarse = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, coarse_policy);
        ws.ensure(coarse.workspace_floats(b, pool.workers()));
        let mut out = Tensor4::zeros(coarse.out_dims(b));

        // Drive the real feedback loop: run on the coarse tiling,
        // measure per-job imbalance, adjust until the signal settles.
        let mut policy = coarse_policy;
        let mut anchor = pool.stats();
        for _ in 0..8 {
            let plan = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
            for _ in 0..4 {
                plan.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None);
            }
            let now = pool.stats();
            // Kernel-origin signal: execute_into runs blocking kernel
            // jobs, and reading the kernel lane mirrors what the
            // scheduler/server consume since jobs gained origins.
            let signal = now.interval_kernel_tiling_signal(&anchor);
            anchor = now;
            match signal.and_then(|(i, s)| policy.adjusted(i, s)) {
                Some(next) => policy = next,
                None => break,
            }
        }
        let adapted = LayerPlan::build_with_policy(&shape, &w, Method::DirectSparse, policy);
        let coarse_t = bench_median(bench, || {
            coarse.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
        });
        let adapted_t = bench_median(bench, || {
            adapted.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
        });
        rows.push(Row {
            shape: "coarse_conv_64x64_sp90",
            method: "retile-adaptive",
            batch: b,
            free_ns: coarse_t.as_nanos(),
            plan_ns: adapted_t.as_nanos(),
        });
        println!(
            "retile-adaptive: coarse({} tiles) {coarse_t:?}  adapted({} tiles) {adapted_t:?}  ({:.2}x)",
            coarse_policy.target_tiles,
            policy.target_tiles,
            coarse_t.as_secs_f64() / adapted_t.as_secs_f64().max(1e-12)
        );
    }

    // Simulator-autotune headline: for each kernel class the offline
    // sweep can retile (register-blocked stride-1, vector-width 13x13,
    // strided row-gather), measure the default-policy plan against the
    // sim-ranked winner — and record the prediction itself (simulated
    // bytes-from-DRAM, default vs tuned) right next to the measured
    // ns/iter. That pairing is the predicted-vs-measured contract
    // documented in rust/src/simulator/README.md: the simulator may
    // only claim a ranking that the measured rows can be diffed
    // against. Shapes are moderate (the sweep replays one full address
    // trace per candidate), batch 1 throughout.
    {
        let tune_shapes: [(&'static str, ConvShape); 3] = [
            (
                "autotune_conv2_5x5_27x27_sp85",
                ConvShape::new(48, 64, 27, 27, 5, 5, 1, 2)
                    .with_groups(2)
                    .with_sparsity(0.85),
            ),
            (
                "autotune_conv3_3x3_13x13_sp88",
                ConvShape::new(128, 192, 13, 13, 3, 3, 1, 1).with_sparsity(0.88),
            ),
            (
                "autotune_3x3_s2_28x28_sp70",
                ConvShape::new(32, 32, 28, 28, 3, 3, 2, 1).with_sparsity(0.7),
            ),
        ];
        let b = 1usize;
        for (name, shape) in &tune_shapes {
            let mut rng = Rng::new(8);
            let w = ConvWeights::synthetic(shape, &mut rng);
            let outcome = autotune_policy(shape, &w, P100_GEOMETRY);
            let default_plan = LayerPlan::build(shape, &w, Method::DirectSparse);
            let tuned_plan =
                LayerPlan::build_with_policy(shape, &w, Method::DirectSparse, outcome.best);
            let x =
                Tensor4::random_activations(Dims4::new(b, shape.c, shape.h, shape.w), &mut rng);
            ws.ensure(
                default_plan
                    .workspace_floats(b, pool.workers())
                    .max(tuned_plan.workspace_floats(b, pool.workers())),
            );
            let mut out = Tensor4::zeros(default_plan.out_dims(b));
            let default_t = bench_median(bench, || {
                default_plan.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            let tuned_t = bench_median(bench, || {
                tuned_plan.execute_into(b, x.data(), &pool, &mut ws, out.data_mut(), None)
            });
            rows.push(Row {
                shape: *name,
                method: "sconv-autotune-b1",
                batch: b,
                free_ns: default_t.as_nanos(),
                plan_ns: tuned_t.as_nanos(),
            });
            // The prediction those measured rows validate: simulated
            // DRAM bytes of the default policy vs the sweep winner
            // (values are bytes, not ns — the row reuses the schema's
            // two integer slots).
            let predicted_default = outcome.default_score().report.dram_bytes;
            let predicted_tuned = outcome.ranked[0].report.dram_bytes;
            rows.push(Row {
                shape: *name,
                method: "autotune-predicted-vs-measured",
                batch: b,
                free_ns: predicted_default as u128,
                plan_ns: predicted_tuned as u128,
            });
            println!(
                "sconv-autotune-b1 {name}: default {default_t:?}  tuned({:?}) {tuned_t:?}  \
                 ({:.2}x measured, {:.2}x predicted-dram)",
                outcome.best,
                default_t.as_secs_f64() / tuned_t.as_secs_f64().max(1e-12),
                predicted_default as f64 / (predicted_tuned as f64).max(1.0)
            );
        }
    }

    // Serving-pipeline headline: ns/request over a paced open-loop
    // stream, sequential executor vs the two-slot pipeline. Pacing
    // (rather than blasting the queue full) is what exposes the win:
    // the pipeline overlaps batch formation and the next batch's head
    // layers with the current batch's tail layers.
    for (batch, label) in [(1usize, "serve-pipeline-b1"), (8usize, "serve-pipeline-b8")] {
        let requests = 64usize;
        let pace = Duration::from_micros(150);
        let wall = |depth: usize| -> Duration {
            let mut runs: Vec<Duration> = (0..3)
                .map(|run| serve_wall(depth, batch, threads, requests, pace, run as u64))
                .collect();
            runs.sort();
            runs[1]
        };
        let sequential = wall(1);
        let pipelined = wall(2);
        rows.push(Row {
            shape: "minicnn_paced_64req",
            method: label,
            batch,
            free_ns: (sequential.as_nanos() / requests as u128).max(1),
            plan_ns: (pipelined.as_nanos() / requests as u128).max(1),
        });
        println!(
            "{label}: sequential {sequential:?}  pipelined {pipelined:?} for {requests} reqs ({:.2}x)",
            sequential.as_secs_f64() / pipelined.as_secs_f64().max(1e-12)
        );
    }

    // Closed-loop load harness: the deterministic seeded Poisson
    // generator driving the two-tenant (minicnn 3:1 microcnn) front
    // door with admission control and a per-request deadline. Reported
    // as SLO rows (p50/p99/throughput/rejections/deadline-hit rate)
    // rather than a free-vs-plan pair; `free_ns`/`plan_ns` mirror
    // p50/p99 so the base schema's positivity checks still apply.
    let mut load_rows: Vec<LoadRow> = Vec::new();
    {
        let requests: usize = std::env::var("ESCOIN_LOADGEN_REQUESTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        for (b, label) in [(1usize, "serve-load-b1"), (8usize, "serve-load-b8")] {
            let window = (4 * b).max(8);
            let server = ServerHandle::start(ServerConfig {
                network: "minicnn".into(),
                tenants: vec!["microcnn".into()],
                batcher: BatcherConfig {
                    batch_size: b,
                    max_wait: Duration::from_millis(1),
                },
                max_queue_depth: 2 * window,
                threads,
                router: RouterConfig {
                    explore_every: 0,
                    ..Default::default()
                },
                replan_every: 0,
                adaptive_tiling: false,
                ..Default::default()
            })
            .expect("server start");
            let cfg = LoadGenConfig {
                seed: 0x10AD + b as u64,
                requests,
                mean_interarrival: Duration::from_micros(200),
                tenant_weights: vec![3, 1],
                deadline: Some(Duration::from_millis(250)),
                window,
            };
            let report = run_load(&server, &cfg).expect("load run");
            server.shutdown().expect("shutdown");
            load_rows.push(LoadRow {
                shape: "minicnn+microcnn_poisson",
                method: label,
                batch: b,
                free_ns: report.p50.as_nanos().max(1),
                plan_ns: report.p99.as_nanos().max(1),
                p50_ns: report.p50.as_nanos().max(1),
                p99_ns: report.p99.as_nanos().max(1),
                throughput_rps_milli: ((report.throughput_rps * 1000.0) as u128).max(1),
                rejected: report.rejected as u128,
                deadline_hit_milli: (report.deadline_hit_rate() * 1000.0).round() as u128,
            });
            println!(
                "{label}: {} reqs p50 {:?} p99 {:?} {:.1} req/s \
                 ({} rejected, deadline hit rate {:.3})",
                report.completed,
                report.p50,
                report.p99,
                report.throughput_rps,
                report.rejected,
                report.deadline_hit_rate()
            );
        }
    }

    // Chaos serving: the same closed-loop harness with a seeded fault
    // plan layered over it — tile panics and NaN poisons target
    // specific serving batches, and the supervised executor degrades
    // (safe-path retry, arena rebuild) instead of dying. With
    // `--features fault-inject` the plan is armed and `failed`/
    // `recovery_ns` measure degradation; without it the identical row
    // is a clean run (failed == 0), so the schema holds on every leg.
    let mut chaos_rows: Vec<ChaosRow> = Vec::new();
    {
        let requests: usize = std::env::var("ESCOIN_LOADGEN_REQUESTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        for (b, label) in [(1usize, "serve-chaos-b1"), (8usize, "serve-chaos-b8")] {
            let window = (4 * b).max(8);
            let server = ServerHandle::start(ServerConfig {
                network: "minicnn".into(),
                batcher: BatcherConfig {
                    batch_size: b,
                    max_wait: Duration::from_millis(1),
                },
                threads,
                router: RouterConfig {
                    explore_every: 0,
                    ..Default::default()
                },
                replan_every: 0,
                adaptive_tiling: false,
                ..Default::default()
            })
            .expect("server start");
            let cfg = LoadGenConfig {
                seed: 0xC4A0 + b as u64,
                requests,
                mean_interarrival: Duration::from_micros(200),
                tenant_weights: Vec::new(),
                deadline: Some(Duration::from_millis(250)),
                window,
            };
            let chaos = ChaosConfig {
                seed: 0xC4A0 + b as u64,
                tile_panics: 2,
                nan_poisons: 2,
                straggle: Some((1, Duration::from_millis(2))),
            };
            let report = run_chaos(&server, &cfg, &chaos).expect("chaos run");
            server.shutdown().expect("shutdown");
            chaos_rows.push(ChaosRow {
                shape: "minicnn_chaos",
                method: label,
                batch: b,
                free_ns: report.p50.as_nanos().max(1),
                plan_ns: report.p99.as_nanos().max(1),
                p50_ns: report.p50.as_nanos().max(1),
                p99_ns: report.p99.as_nanos().max(1),
                failed: report.failed as u128,
                shed: report.shed as u128,
                recovery_ns: report.recovery.as_nanos(),
                deadline_hit_milli: (report.deadline_hit_rate() * 1000.0).round() as u128,
            });
            println!(
                "{label}: {} completed / {} failed / {} shed, p50 {:?} p99 {:?}, \
                 recovery {:?}, deadline hit rate {:.3}",
                report.completed,
                report.failed,
                report.shed,
                report.p50,
                report.p99,
                report.recovery,
                report.deadline_hit_rate()
            );
        }
    }

    // DAG-vs-sequential walk on GoogLeNet: the async branch-overlap
    // executor against the sequential topological walk, same compiled
    // plan, same shared pool — what the inception modules' 4-way
    // branch/merge graph buys end to end.
    {
        let net = googlenet();
        for (b, label) in [(1usize, "googlenet-dag-b1"), (8usize, "googlenet-dag-b8")] {
            let plan = NetworkPlan::build(&net, b, 42, |_, _| Method::DirectSparse);
            let mut arena = WorkspaceArena::for_plan(&plan, &pool);
            let sequential = bench_median(bench, || {
                plan.run(&pool, &mut arena);
            });
            let dag = bench_median(bench, || {
                plan.run_async(None, &pool, &mut arena);
            });
            rows.push(Row {
                shape: "googlenet",
                method: label,
                batch: b,
                free_ns: sequential.as_nanos(),
                plan_ns: dag.as_nanos(),
            });
            println!(
                "{label}: sequential-walk {sequential:?}  dag-walk {dag:?} ({:.2}x)",
                sequential.as_secs_f64() / dag.as_secs_f64().max(1e-12)
            );
        }
    }

    // DAG-vs-sequential walk on ResNet-50's residual graph: every
    // bottleneck's main path and shortcut are real branches joined by
    // an elementwise Add merge, so the async walk can overlap the
    // shortcut's downsample conv with the main 1x1-3x3-1x1 chain.
    // Batch 1 only — the network is ~4x GoogLeNet's MACs.
    {
        let net = resnet50();
        let b = 1usize;
        let plan = NetworkPlan::build(&net, b, 42, |_, _| Method::DirectSparse);
        let mut arena = WorkspaceArena::for_plan(&plan, &pool);
        let sequential = bench_median(bench, || {
            plan.run(&pool, &mut arena);
        });
        let dag = bench_median(bench, || {
            plan.run_async(None, &pool, &mut arena);
        });
        rows.push(Row {
            shape: "resnet50",
            method: "resnet50-dag-b1",
            batch: b,
            free_ns: sequential.as_nanos(),
            plan_ns: dag.as_nanos(),
        });
        println!(
            "resnet50-dag-b1: sequential-walk {sequential:?}  dag-walk {dag:?} ({:.2}x)",
            sequential.as_secs_f64() / dag.as_secs_f64().max(1e-12)
        );
    }

    // Whole-network MobileNetV1 at batch 1: every conv planned with the
    // per-channel gather policy (`TilePolicy::unblocked()`) vs the
    // default blocked policy, identical weight stream (both walks
    // replicate `NetworkPlan::build`'s seeded RNG order) — the
    // end-to-end win of the grouped/strided blocked kernels on a
    // depthwise-separable network.
    {
        let net = mobilenetv1();
        let b = 1usize;
        let build_with = |policy: TilePolicy| -> NetworkPlan {
            let mut rng = Rng::new(42);
            NetworkPlan::from_parts(&net, b, &mut |layer| match &layer.kind {
                LayerKind::Conv(shape) => {
                    let w = Arc::new(ConvWeights::synthetic(shape, &mut rng));
                    let method = if shape.is_sparse() {
                        Method::DirectSparse
                    } else {
                        Method::LoweredGemm
                    };
                    Some(WeightedOp::Conv(Arc::new(
                        LayerPlan::build_shared_with_policy(shape, w, method, policy),
                    )))
                }
                LayerKind::Fc(fc) => Some(WeightedOp::Fc(Arc::new(rng.normal_vec(fc.weights())))),
                _ => None,
            })
        };
        let gather = build_with(TilePolicy::unblocked());
        let blocked = build_with(TilePolicy::default());
        let mut gather_arena = WorkspaceArena::for_plan(&gather, &pool);
        let mut blocked_arena = WorkspaceArena::for_plan(&blocked, &pool);
        let gather_t = bench_median(bench, || {
            gather.run(&pool, &mut gather_arena);
        });
        let blocked_t = bench_median(bench, || {
            blocked.run(&pool, &mut blocked_arena);
        });
        rows.push(Row {
            shape: "mobilenetv1",
            method: "mobilenet-b1",
            batch: b,
            free_ns: gather_t.as_nanos(),
            plan_ns: blocked_t.as_nanos(),
        });
        println!(
            "mobilenet-b1: per-channel-gather {gather_t:?}  blocked {blocked_t:?} ({:.2}x)",
            gather_t.as_secs_f64() / blocked_t.as_secs_f64().max(1e-12)
        );
    }

    // Replan cost: the old executor rebuilt every layer (weights
    // regenerated, operands re-stretched / re-CSR'd) on any router
    // flip; the PlanCache rebuilds only the flipped layer.
    {
        let net = alexnet();
        let serve_batch = 4usize;
        let full = bench_median(bench, || {
            NetworkPlan::build(&net, serve_batch, 42, |_, _| Method::DirectSparse)
        });
        let cache = PlanCache::build(&net, 42);
        // Prime both assignments so the loop below measures the
        // steady-state incremental replan (assemble + one cached flip).
        let _ = cache.network_plan(&net, serve_batch, |_, _| Method::DirectSparse);
        let _ = cache.network_plan(&net, serve_batch, |n, _| {
            if n == "conv3" {
                Method::LoweredSpmm
            } else {
                Method::DirectSparse
            }
        });
        let mut flip = false;
        let incremental = bench_median(bench, || {
            flip = !flip;
            cache.network_plan(&net, serve_batch, |n, _| {
                if flip && n == "conv3" {
                    Method::LoweredSpmm
                } else {
                    Method::DirectSparse
                }
            })
        });
        rows.push(Row {
            shape: "alexnet_b4",
            method: "replan-full-vs-incremental",
            batch: serve_batch,
            free_ns: full.as_nanos(),
            plan_ns: incremental.as_nanos(),
        });
        println!(
            "replan alexnet b{serve_batch}: full rebuild {full:?}  incremental {incremental:?} ({:.1}x)",
            full.as_secs_f64() / incremental.as_secs_f64().max(1e-12)
        );
    }

    let mut json = String::from("{\n  \"bench\": \"sconv\",\n  \"unit\": \"ns_per_iter\",\n");
    json.push_str(&format!(
        "  \"threads\": {threads},\n  \"batch\": {batch},\n  \"iters\": {},\n  \"rows\": [\n",
        bench.iters
    ));
    let mut entries: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"shape\": \"{}\", \"method\": \"{}\", \"batch\": {}, \
                 \"free_ns\": {}, \"plan_ns\": {}}}",
                r.shape, r.method, r.batch, r.free_ns, r.plan_ns
            )
        })
        .collect();
    entries.extend(load_rows.iter().map(|r| {
        format!(
            "    {{\"shape\": \"{}\", \"method\": \"{}\", \"batch\": {}, \
             \"free_ns\": {}, \"plan_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \
             \"throughput_rps_milli\": {}, \"rejected\": {}, \"deadline_hit_milli\": {}}}",
            r.shape,
            r.method,
            r.batch,
            r.free_ns,
            r.plan_ns,
            r.p50_ns,
            r.p99_ns,
            r.throughput_rps_milli,
            r.rejected,
            r.deadline_hit_milli
        )
    }));
    entries.extend(chaos_rows.iter().map(|r| {
        format!(
            "    {{\"shape\": \"{}\", \"method\": \"{}\", \"batch\": {}, \
             \"free_ns\": {}, \"plan_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \
             \"failed\": {}, \"shed\": {}, \"recovery_ns\": {}, \"deadline_hit_milli\": {}}}",
            r.shape,
            r.method,
            r.batch,
            r.free_ns,
            r.plan_ns,
            r.p50_ns,
            r.p99_ns,
            r.failed,
            r.shed,
            r.recovery_ns,
            r.deadline_hit_milli
        )
    }));
    json.push_str(&entries.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write(&out_path, &json).expect("write BENCH_sconv.json");
    println!("wrote {out_path}");

    // Report the headline comparison; the plan path skips the per-call
    // pad/output allocation and thread spawns, so it is expected to win
    // — warn loudly (but don't fail: wall-clock ratios are noisy on
    // shared machines) when a regression shows up, and let future PRs
    // diff BENCH_sconv.json.
    let sconv_rows: Vec<&Row> = rows.iter().filter(|r| r.method == "sconv").collect();
    let free: u128 = sconv_rows.iter().map(|r| r.free_ns).sum();
    let plan: u128 = sconv_rows.iter().map(|r| r.plan_ns).sum();
    println!(
        "plan-based sconv total {plan} ns vs free-function {free} ns ({:.2}x)",
        free as f64 / plan as f64
    );
    if plan > free {
        eprintln!("WARNING: plan-based sconv slower than the seed free-function path");
    }
}

/// Wall time to serve `requests` paced submissions (one every `pace`)
/// through a minicnn server at the given pipeline depth — replans and
/// exploration disabled so both depths execute the identical plan.
fn serve_wall(
    depth: usize,
    batch: usize,
    threads: usize,
    requests: usize,
    pace: Duration,
    seed: u64,
) -> Duration {
    let server = ServerHandle::start(ServerConfig {
        network: "minicnn".into(),
        batcher: BatcherConfig {
            batch_size: batch,
            max_wait: Duration::from_millis(1),
        },
        weight_seed: 42,
        threads,
        router: RouterConfig {
            explore_every: 0,
            ..Default::default()
        },
        replan_every: 0,
        pipeline_depth: depth,
        adaptive_tiling: false,
        ..Default::default()
    })
    .expect("server start");
    let mut rng = Rng::new(100 + seed);
    let elems = server.image_elems();
    let images: Vec<Vec<f32>> = (0..requests).map(|_| rng.activation_vec(elems)).collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for img in images {
        pending.push(server.submit(img).expect("submit"));
        std::thread::sleep(pace);
    }
    for rx in pending {
        rx.recv().expect("response channel").expect("response");
    }
    let wall = t0.elapsed();
    server.shutdown().expect("shutdown");
    wall
}
