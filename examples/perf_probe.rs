use escoin::config::ConvShape;
use escoin::conv::*;
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::Rng;
use std::time::Instant;

fn main() {
    let threads = 8;
    for (name, shape) in [
        ("conv2 (5x5, 27x27, sp.85)", ConvShape::new(96, 256, 27, 27, 5, 5, 1, 2).with_groups(2).with_sparsity(0.85)),
        ("conv3 (3x3, 13x13, sp.88)", ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88)),
        ("conv3/2 (3x3, 6x6)", ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88).scaled_spatial(2)),
    ] {
        let mut rng = Rng::new(1);
        let x = Tensor4::random_activations(Dims4::new(2, shape.c, shape.h, shape.w), &mut rng);
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let banks = w.csr_banks();
        let st = w.stretched_banks();
        let t0 = Instant::now();
        let _ = lowered_gemm_parallel(&shape, &x, &w, threads);
        let g = t0.elapsed();
        let t0 = Instant::now();
        let _ = lowered_spmm_parallel(&shape, &x, &banks, threads);
        let s = t0.elapsed();
        let t0 = Instant::now();
        let _ = sconv_parallel(&shape, &x, &st, threads);
        let d = t0.elapsed();
        println!("{name}: gemm {g:?} spmm {s:?} sconv {d:?}");
    }
}
