//! End-to-end serving driver (the DESIGN.md E2E validation): start the
//! coordinator on a shared NetworkPlan, fire a stream of single-image
//! requests through the dynamic batcher, and report latency/throughput.
//!
//! ```text
//! cargo run --release --example serve_inference [requests] [network] [--threads N]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use escoin::coordinator::{BatcherConfig, RouterConfig, ServerConfig, ServerHandle};
use escoin::util::{default_threads, Rng};
use std::time::{Duration, Instant};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // Like main.rs's take_threads: the flag and its value are always
    // consumed once seen, so a bad value cannot shift the positionals.
    let threads = match args.iter().position(|a| a == "--threads") {
        Some(i) => {
            let value = args.get(i + 1).cloned();
            args.drain(i..(i + 2).min(args.len()));
            match value.as_deref().map(str::parse::<usize>) {
                Some(Ok(n)) if n > 0 => n,
                _ => {
                    eprintln!("--threads wants a positive integer; using default");
                    default_threads()
                }
            }
        }
        None => default_threads(),
    };
    let total: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(256);
    let network = args.get(1).cloned().unwrap_or_else(|| "minicnn".to_string());

    println!("starting server on {network} ({threads} threads) ...");
    let t0 = Instant::now();
    let server = ServerHandle::start(ServerConfig {
        network: network.clone(),
        batcher: BatcherConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(2),
        },
        weight_seed: 42,
        threads,
        router: RouterConfig::default(),
        ..Default::default()
    })?;
    println!(
        "server ready in {:?} (image elems {}, classes {})",
        t0.elapsed(),
        server.image_elems(),
        server.num_classes()
    );

    let mut rng = Rng::new(1);
    let elems = server.image_elems();
    let t_run = Instant::now();
    let mut pending = Vec::with_capacity(total);
    for _ in 0..total {
        pending.push(server.submit(rng.activation_vec(elems))?);
    }
    let mut latencies = Vec::with_capacity(total);
    for rx in pending {
        let resp = rx.recv()??;
        latencies.push(resp.latency.as_secs_f64() * 1e3);
    }
    let wall = t_run.elapsed();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| latencies[((q * (total - 1) as f64) as usize).min(total - 1)];

    let m = server.metrics();
    println!("--- E2E serving results ({network}) ---");
    println!("requests:       {total}");
    println!("wall time:      {wall:?}");
    println!("throughput:     {:.1} images/s", total as f64 / wall.as_secs_f64());
    println!("latency p50:    {:.2} ms", p(0.50));
    println!("latency p95:    {:.2} ms", p(0.95));
    println!("latency p99:    {:.2} ms", p(0.99));
    println!("batches:        {} (padded slots {})", m.batches, m.padded_slots);
    let stats = server.shutdown()?;
    let s = &stats.snapshot;
    println!("plan build:     {:?}", stats.plan_build_time);
    println!(
        "replans:        {} ({} layer plans rebuilt, {:?} spent rebuilding)",
        stats.replans, s.replan_layers_rebuilt, s.replan_build_time
    );
    println!(
        "pool:           {} workers, {} tiles ({} stolen), imbalance {:.2}",
        s.pool_workers, s.pool_tiles, s.pool_steals, s.pool_imbalance
    );
    assert_eq!(stats.snapshot.errors, 0, "no batch may fail");
    Ok(())
}
