//! End-to-end serving driver (the DESIGN.md E2E validation): start the
//! coordinator on a MiniCNN model artifact, fire a stream of single-image
//! requests through the dynamic batcher, and report latency/throughput.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_inference [requests] [artifact]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use escoin::coordinator::{BatcherConfig, ServerConfig, ServerHandle};
use escoin::util::Rng;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let total: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(256);
    let artifact = args
        .get(2)
        .cloned()
        .unwrap_or_else(|| "minicnn_sconv".to_string());

    println!("starting server on {artifact} ...");
    let t0 = Instant::now();
    let server = ServerHandle::start(ServerConfig {
        artifact_dir: "artifacts".into(),
        artifact: artifact.clone(),
        batcher: BatcherConfig {
            batch_size: 4, // overridden by the artifact's static batch
            max_wait: Duration::from_millis(2),
        },
        weight_seed: 42,
    })?;
    println!(
        "server ready in {:?} (image elems {}, classes {})",
        t0.elapsed(),
        server.image_elems(),
        server.num_classes()
    );

    let mut rng = Rng::new(1);
    let elems = server.image_elems();
    let t_run = Instant::now();
    let mut pending = Vec::with_capacity(total);
    for _ in 0..total {
        pending.push(server.submit(rng.activation_vec(elems))?);
    }
    let mut latencies = Vec::with_capacity(total);
    for rx in pending {
        let resp = rx.recv()?;
        latencies.push(resp.latency.as_secs_f64() * 1e3);
    }
    let wall = t_run.elapsed();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| latencies[((q * (total - 1) as f64) as usize).min(total - 1)];

    let m = server.metrics();
    println!("--- E2E serving results ({artifact}) ---");
    println!("requests:       {total}");
    println!("wall time:      {wall:?}");
    println!("throughput:     {:.1} images/s", total as f64 / wall.as_secs_f64());
    println!("latency p50:    {:.2} ms", p(0.50));
    println!("latency p95:    {:.2} ms", p(0.95));
    println!("latency p99:    {:.2} ms", p(0.99));
    println!("batches:        {} (padded slots {})", m.batches, m.padded_slots);
    let stats = server.shutdown()?;
    println!("model compile:  {:?}", stats.compile_time);
    assert_eq!(stats.snapshot.errors, 0, "no batch may fail");
    Ok(())
}
