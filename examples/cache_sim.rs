//! Drive the GPU memory-hierarchy simulator directly on one layer:
//! replay each kernel's access stream and print hit rates + DRAM
//! traffic — then run the offline `TilePolicy` autotuner on the same
//! layer and print the sweep's ranking (the simulated costs plan
//! compilation bakes winners from).
//!
//! ```text
//! cargo run --release --example cache_sim -- [sparsity]
//! ```

use escoin::bench_harness::Table;
use escoin::config::ConvShape;
use escoin::conv::{ConvWeights, SparseLayout, TilePolicy};
use escoin::simulator::{
    autotune_policy_p100, trace_csrmm, trace_im2col, trace_sconv, trace_sconv_microkernel,
    trace_sgemm, MemoryHierarchy,
};
use escoin::sparse::BalancedCsr;
use escoin::util::Rng;

fn policy_label(p: &TilePolicy) -> String {
    let block = if p.block_floats == usize::MAX {
        "all".to_string()
    } else {
        p.block_floats.to_string()
    };
    let layout = match p.layout {
        SparseLayout::Csr => "csr",
        SparseLayout::Balanced => "bal",
    };
    format!(
        "tiles={} mr={} block={} lanes={} {}",
        p.target_tiles, p.mr, block, p.lanes, layout
    )
}

fn main() {
    let sparsity: f32 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.88);
    let mut shape = ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1);
    if sparsity > 0.0 {
        shape = shape.with_sparsity(sparsity);
    }
    println!("layer: AlexNet conv3 class, {shape}");
    let mut rng = Rng::new(3);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let (k, ef) = shape.lowered_dims();
    let banks = w.stretched_banks();

    let mut t = Table::new(
        "Simulated P100 memory behaviour per kernel",
        &["kernel", "RO hit", "L2 hit", "DRAM MB", "warp transactions", "scalar ops"],
    );
    let mut run = |name: &str, f: &mut dyn FnMut(&mut MemoryHierarchy) -> u64| {
        let mut mem = MemoryHierarchy::p100();
        let scalars = f(&mut mem);
        let r = mem.report();
        t.row(vec![
            name.to_string(),
            format!("{:.0}%", 100.0 * r.ro_hit_rate()),
            format!("{:.0}%", 100.0 * r.l2_hit_rate()),
            format!("{:.2}", r.dram_bytes as f64 / 1e6),
            r.transactions.to_string(),
            scalars.to_string(),
        ]);
    };
    run("im2col (lowering tax)", &mut |m| {
        trace_im2col(&shape, m).scalar_accesses
    });
    run("sgemm (CUBLAS core)", &mut |m| {
        trace_sgemm(shape.m, k, ef, m).scalar_accesses
    });
    run("csrmm (CUSPARSE core)", &mut |m| {
        trace_csrmm(&w.csr_banks()[0], ef, m).scalar_accesses
    });
    run("sconv (Escoin)", &mut |m| {
        trace_sconv(&shape, &banks[0], m).scalar_accesses
    });
    // The microkernels the plan layer actually dispatches today, at the
    // default policy and a vectorized/bank-balanced variant — traced
    // with the same generators the autotuner scores candidates through.
    let scalar = TilePolicy {
        lanes: 1,
        layout: SparseLayout::Csr,
        ..TilePolicy::default()
    };
    run("sconv-blocked (mr-block microkernel)", &mut |m| {
        trace_sconv_microkernel(&shape, &banks, None, &scalar, m).scalar_accesses
    });
    let vector = TilePolicy {
        lanes: escoin::conv::SIMD_LANES,
        ..scalar
    };
    let balanced: Vec<BalancedCsr> = banks
        .iter()
        .map(|b| BalancedCsr::from_csr(&b.csr, vector.mr.max(1)))
        .collect();
    run("sconv-balanced (vector microkernel)", &mut |m| {
        trace_sconv_microkernel(&shape, &banks, Some(&balanced), &vector, m).scalar_accesses
    });
    print!("{}", t.render());
    println!(
        "note: lowering approaches pay im2col + their matmul; Escoin pays sconv only."
    );

    // The offline sweep plan compilation runs (`ServerConfig::
    // autotune_policies` / `NetworkSchedule::autotune_tiling`): every
    // candidate geometry ranked by simulated DRAM traffic, winner
    // first. Deterministic — same layer, same table.
    let outcome = autotune_policy_p100(&shape, &w);
    let mut sweep = Table::new(
        "TilePolicy autotune sweep (ranked, winner first)",
        &["policy", "DRAM MB", "L2 miss", "RO miss", "RO hit"],
    );
    for s in &outcome.ranked {
        sweep.row(vec![
            policy_label(&s.policy),
            format!("{:.2}", s.report.dram_bytes as f64 / 1e6),
            s.report.l2.misses.to_string(),
            s.report.ro.misses.to_string(),
            format!("{:.0}%", 100.0 * s.report.ro_hit_rate()),
        ]);
    }
    print!("{}", sweep.render());
    let best = outcome.ranked[0].report.dram_bytes as f64;
    let default = outcome.default_score().report.dram_bytes as f64;
    println!(
        "winner: {} ({:.2}x less predicted DRAM traffic than the default policy)",
        policy_label(&outcome.best),
        default / best.max(1.0)
    );
}
