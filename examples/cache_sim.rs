//! Drive the GPU memory-hierarchy simulator directly on one layer:
//! replay each kernel's access stream and print hit rates + DRAM traffic.
//!
//! ```text
//! cargo run --release --example cache_sim -- [sparsity]
//! ```

use escoin::bench_harness::Table;
use escoin::config::ConvShape;
use escoin::conv::ConvWeights;
use escoin::simulator::{
    trace_csrmm, trace_im2col, trace_sconv, trace_sgemm, MemoryHierarchy,
};
use escoin::util::Rng;

fn main() {
    let sparsity: f32 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.88);
    let mut shape = ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1);
    if sparsity > 0.0 {
        shape = shape.with_sparsity(sparsity);
    }
    println!("layer: AlexNet conv3 class, {shape}");
    let mut rng = Rng::new(3);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let (k, ef) = shape.lowered_dims();

    let mut t = Table::new(
        "Simulated P100 memory behaviour per kernel",
        &["kernel", "RO hit", "L2 hit", "DRAM MB", "warp transactions", "scalar ops"],
    );
    let mut run = |name: &str, f: &mut dyn FnMut(&mut MemoryHierarchy) -> u64| {
        let mut mem = MemoryHierarchy::p100();
        let scalars = f(&mut mem);
        let r = mem.report();
        t.row(vec![
            name.to_string(),
            format!("{:.0}%", 100.0 * r.ro_hit_rate()),
            format!("{:.0}%", 100.0 * r.l2_hit_rate()),
            format!("{:.2}", r.dram_bytes as f64 / 1e6),
            r.transactions.to_string(),
            scalars.to_string(),
        ]);
    };
    run("im2col (lowering tax)", &mut |m| {
        trace_im2col(&shape, m).scalar_accesses
    });
    run("sgemm (CUBLAS core)", &mut |m| {
        trace_sgemm(shape.m, k, ef, m).scalar_accesses
    });
    run("csrmm (CUSPARSE core)", &mut |m| {
        trace_csrmm(&w.csr_banks()[0], ef, m).scalar_accesses
    });
    run("sconv (Escoin)", &mut |m| {
        trace_sconv(&shape, &w.stretched_banks()[0], m).scalar_accesses
    });
    print!("{}", t.render());
    println!(
        "note: lowering approaches pay im2col + their matmul; Escoin pays sconv only."
    );
}
