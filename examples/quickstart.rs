//! Quickstart: compile one AlexNet layer into a `LayerPlan` per method,
//! check the three contenders agree, then race them at the paper's full
//! layer size through the plan executor (reused workspace, kernel-only
//! timing).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! (The PJRT/AOT artifact path is behind the `pjrt` cargo feature; see
//! `escoin infer`.)

use escoin::config::ConvShape;
use escoin::conv::{ConvWeights, LayerPlan, Method, Workspace};
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::{default_threads, Rng, WorkerPool};
use std::time::Instant;

fn main() {
    // One worker pool for the whole run — every plan executes on it.
    let pool = WorkerPool::new(default_threads());

    // --- Part 1: the three methods agree on a small layer. ---
    let shape = ConvShape::new(16, 32, 14, 14, 3, 3, 1, 1).with_sparsity(0.8);
    let mut rng = Rng::new(7);
    let x = Tensor4::random_activations(Dims4::new(2, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    println!("layer {shape}: three methods through compiled plans");
    let mut outputs = Vec::new();
    for method in [Method::LoweredGemm, Method::LoweredSpmm, Method::DirectSparse] {
        let plan = LayerPlan::build(&shape, &w, method);
        let t0 = Instant::now();
        let y = plan.run(&x, &pool);
        println!(
            "  {:>13}: out {} in {:?} (workspace {} floats)",
            method.name(),
            y.dims(),
            t0.elapsed(),
            plan.workspace_floats(2, pool.workers())
        );
        outputs.push(y);
    }
    for pair in outputs.windows(2) {
        assert!(pair[0].allclose(&pair[1], 1e-3, 1e-3), "methods disagree!");
    }
    println!("  all three methods agree.");

    // --- Part 2: the paper's full AlexNet conv3, kernel-only timing. ---
    let shape = ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88);
    let mut rng = Rng::new(8);
    let x = Tensor4::random_activations(Dims4::new(4, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let mut ws = Workspace::new();
    let mut time = |method: Method| {
        let plan = LayerPlan::build(&shape, &w, method);
        ws.ensure(plan.workspace_floats(4, pool.workers()));
        let mut out = Tensor4::zeros(plan.out_dims(4));
        let t0 = Instant::now();
        plan.execute_into(4, x.data(), &pool, &mut ws, out.data_mut(), None);
        (t0.elapsed(), out)
    };
    let (t_dense, dense) = time(Method::LoweredGemm);
    let (t_sparse, sparse) = time(Method::DirectSparse);
    assert!(sparse.allclose(&dense, 1e-3, 1e-3));
    println!(
        "native AlexNet conv3 (sparsity 0.88, batch 4): lowering+GEMM {t_dense:?} vs \
         Escoin {t_sparse:?} ({:.2}x)",
        t_dense.as_secs_f64() / t_sparse.as_secs_f64()
    );
}
