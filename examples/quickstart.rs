//! Quickstart: load one AOT conv-layer artifact, run all three methods on
//! the same inputs through PJRT, check they agree, and show the native
//! Escoin kernel on the full-size layer.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use escoin::config::ConvShape;
use escoin::conv::{lowered_gemm_parallel, sconv_parallel, ConvWeights};
use escoin::runtime::Engine;
use escoin::tensor::{Dims4, Tensor4};
use escoin::util::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // --- Part 1: the AOT path (Pallas kernels -> HLO -> PJRT). ---
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());
    let layer = "alexnet_conv3";
    println!("layer {layer}: three methods through the compiled artifacts");
    let mut outputs = Vec::new();
    for method in ["gemm", "spmm", "sconv"] {
        let loaded = engine.load(&format!("{layer}_{method}"))?;
        let shape = loaded.artifact.shape.clone().unwrap();
        let mut rng = Rng::new(7);
        let x = Tensor4::random_activations(
            Dims4::new(loaded.artifact.batch, shape.c, shape.h, shape.w),
            &mut rng,
        );
        let w = ConvWeights::synthetic(&shape, &mut rng);
        let lits = loaded.weight_literals(&w)?;
        let t0 = Instant::now();
        let y = loaded.run(&x, &lits)?;
        println!(
            "  {method:>5}: out {} in {:?} (compile {:?})",
            y.dims(),
            t0.elapsed(),
            loaded.compile_time
        );
        outputs.push(y);
    }
    for pair in outputs.windows(2) {
        assert!(
            pair[0].allclose(&pair[1], 1e-3, 1e-3),
            "methods disagree!"
        );
    }
    println!("  all three methods agree.");

    // --- Part 2: the native kernel at the paper's full layer size. ---
    let shape = ConvShape::new(256, 384, 13, 13, 3, 3, 1, 1).with_sparsity(0.88);
    let mut rng = Rng::new(8);
    let x = Tensor4::random_activations(Dims4::new(4, shape.c, shape.h, shape.w), &mut rng);
    let w = ConvWeights::synthetic(&shape, &mut rng);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let t0 = Instant::now();
    let dense = lowered_gemm_parallel(&shape, &x, &w, threads);
    let t_dense = t0.elapsed();
    let banks = w.stretched_banks();
    let t0 = Instant::now();
    let sparse = sconv_parallel(&shape, &x, &banks, threads);
    let t_sparse = t0.elapsed();
    assert!(sparse.allclose(&dense, 1e-3, 1e-3));
    println!(
        "native AlexNet conv3 (sparsity 0.88, batch 4): lowering+GEMM {t_dense:?} vs \
         Escoin {t_sparse:?} ({:.2}x)",
        t_dense.as_secs_f64() / t_sparse.as_secs_f64()
    );
    Ok(())
}
